"""Bridge between model configs and the paper's (s_m, s_c) service spec, plus
the two KV-cache layouts used by chain engines: slotted and paged.

The paper's memory model:  server memory = s_m * (#blocks) + s_c * (cache
slots in use).  For a transformer served at max sequence length S_max with
TP degree t:  s_m = per-layer weight bytes / t;  s_c = per-layer KV bytes per
token * S_max / t (static allocation, Section 2.1.2).  For recurrent layers
(xLSTM / SSM) the "KV" is the recurrent state: size independent of S_max —
the chain-composition algorithms are unchanged (DESIGN.md §4).

Layouts
-------
``SlotCache`` is the paper's Section 2.1.2 allocation taken literally: one
``(layers, capacity, S_max, ...)`` buffer per cache leaf, slot i owned by
request i for its whole lifetime.  Admission pays an O(capacity * S_max)
whole-cache copy per request and decode always computes all ``capacity``
rows.

``PagedCache`` keeps the *accounting* of that model while dropping its
allocation granularity: every sequence-length-bearing leaf becomes one
pooled buffer of fixed ``page_size``-token pages, and a per-slot block
table maps logical positions to pages.  Prefill scatters O(prompt) pages
into the pool (donated buffers — no copy of untouched pages), decode
allocates one page on demand as a sequence crosses a page boundary, and
release returns pages to a free stack without zeroing (stale keys are
masked by per-slot lengths and overwritten by the next prefill).

The paper's memory model is preserved exactly: a slot's ``s_c`` gigabytes
shard into ``pages_per_slot = ceil(S_max / page_size)`` pages of
``s_c / pages_per_slot`` GB each (:class:`PageAccounting`), so a
``PagedCache`` with ``capacity * pages_per_slot`` pages occupies precisely
the memory GCA granted for ``capacity`` slots — pages are the allocation
unit, ``s_c`` stays the control-plane contract.  Oversubscription
(``num_slots > capacity`` at the same page budget) is how paging converts
short-sequence slack into effective capacity; exhaustion is handled by
deferring admission and preempting the youngest request, never by UB.

Leaves whose shape does not scale with S_max — recurrent/SSM state, and
sliding-window rings smaller than S_max — stay slot-resident (a
``(layers, num_slots, ...)`` buffer), matching the paper's treatment of
recurrent state as seq-independent.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.servers import Server, ServiceSpec
from repro.models import Model
from repro.models.transformer import stages


GIB = 1024.0 ** 3


def recurrent_state_bytes(cfg: ModelConfig, bytes_per_el: int = 4) -> float:
    """Per-request per-layer recurrent-state bytes (mLSTM/sLSTM/SSM)."""
    if cfg.family == "ssm":
        H, hd = cfg.num_heads, cfg.hd
        mlstm = (H * hd * hd + H * hd) * bytes_per_el
        slstm = 4 * cfg.d_model * bytes_per_el
        return max(mlstm, slstm)
    if cfg.family == "hybrid":
        d_inner = cfg.ssm.expand * cfg.d_model
        return d_inner * cfg.ssm.state_dim * bytes_per_el
    return 0.0


def service_spec_for(
    cfg: ModelConfig, max_seq: int, tp_degree: int = 1, bytes_per_el: int = 2,
) -> ServiceSpec:
    """The paper's (L, s_m, s_c) for serving ``cfg`` at ``max_seq``."""
    s_m = cfg.block_bytes(bytes_per_el) / tp_degree / GIB
    kv = cfg.kv_bytes_per_token_per_layer(bytes_per_el) * max_seq
    if cfg.family == "hybrid":
        # SWA layers cache only the window; global layers the full context.
        n_glob = len(cfg.global_attn_layers)
        frac = (n_glob + (cfg.num_layers - n_glob)
                * min(cfg.window, max_seq) / max_seq) / cfg.num_layers
        kv = kv * frac
    if cfg.family == "ssm":
        kv = 0.0
    kv += recurrent_state_bytes(cfg)
    s_c = max(kv, 1.0) / tp_degree / GIB
    return ServiceSpec(num_blocks=cfg.num_layers, block_size_gb=s_m,
                       cache_size_gb=max(s_c, 1e-9))


def tau_estimates(
    cfg: ModelConfig,
    mean_in_tokens: float,
    mean_out_tokens: float,
    tflops: float = 197.0,
    hbm_gb_per_ms: float = 0.819,
    chips: int = 16,
    overhead_ms: float = 1.0,
) -> float:
    """tau_j^p per the paper's footnote 11: prefill is compute-bound
    (t_I = FLOPs-per-block-per-token / peak), decode memory-bound
    (t_O = block bytes / HBM bandwidth).  Returns seconds per block per job."""
    n_active = cfg.active_layer_param_count()
    flops_per_tok = 2 * n_active
    t_in = flops_per_tok / (tflops * 1e9) / chips            # ms per token
    t_out = cfg.block_bytes() / 1e6 / hbm_gb_per_ms / 1e3 / chips   # ms
    tau_ms = overhead_ms + t_in * mean_in_tokens + t_out * max(mean_out_tokens - 1, 0)
    return tau_ms / 1e3


# ---------------------------------------------------------------------------
# Slotted batched cache
# ---------------------------------------------------------------------------

class SlotCache:
    """Capacity-``c`` batched cache for one chain engine.  Slot i of every
    cache leaf (axis 1, after the per-stage layer axis) belongs to request i.
    """

    def __init__(self, model: Model, capacity: int, max_seq: int,
                 device=None, materialize: bool = True):
        self.model = model
        self.capacity = capacity
        self.max_seq = max_seq
        self.device = device
        if materialize:
            cache = model.init_cache(capacity, max_seq)
            if device is not None:
                cache = jax.device_put(cache, device)
            self.cache = cache
        else:
            # accounting-only master: slot lifecycle without leaves (the
            # leaves live in per-stage leaf_range views)
            self.cache = None
        self.free: List[int] = list(range(capacity))
        self._active: set = set()
        self.lengths = np.zeros((capacity,), np.int32)

    def leaf_range(self, model_slice, device=None) -> "SlotCache":
        """A pipeline-stage view: its own device-resident cache leaves for
        ``model_slice``'s layer range, sharing this cache's slot accounting
        (free list, active set, lengths) *by reference* — acquire/release on
        any view or the master is visible to all."""
        view = SlotCache(model_slice, self.capacity, self.max_seq,
                         device=device)
        view.free = self.free
        view._active = self._active
        view.lengths = self.lengths
        return view

    def acquire(self) -> Optional[int]:
        if not self.free:
            return None
        slot = self.free.pop()
        self._active.add(slot)
        return slot

    def release(self, slot: int) -> None:
        self.lengths[slot] = 0
        self._active.discard(slot)
        self.free.append(slot)

    def write_prefill(self, slot: int, cache_one: Any, prompt_len: int) -> None:
        """Insert a batch-1 prefilled cache into slot ``slot``."""
        self.cache = jax.tree.map(
            lambda full, one: full.at[:, slot].set(one[:, 0]), self.cache, cache_one)
        self.lengths[slot] = prompt_len

    @property
    def active_slots(self) -> List[int]:
        return sorted(self._active)


# ---------------------------------------------------------------------------
# Paged cache
# ---------------------------------------------------------------------------

PAGE_SIZE = 16


@dataclasses.dataclass(frozen=True)
class PageAccounting:
    """Pages <-> s_c: the paper's cache-slot grant expressed in page units.

    One slot's ``s_c`` gigabytes shard into ``pages_per_slot`` pages, so
    ``gb_for_pages(pages_per_slot) == slot_gb`` *exactly* (the round-trip is
    ``slot_gb * (p / pages_per_slot)``, and ``p / pages_per_slot == 1.0`` is
    exact for ``p == pages_per_slot``) — GCA allocations stated in slots and
    pool budgets stated in pages describe the same bytes.
    """

    slot_gb: float            # the paper's s_c for one slot at S_max
    max_seq: int
    page_size: int = PAGE_SIZE

    @classmethod
    def from_spec(cls, spec: ServiceSpec, max_seq: int,
                  page_size: int = PAGE_SIZE) -> "PageAccounting":
        return cls(slot_gb=spec.cache_size_gb, max_seq=max_seq,
                   page_size=page_size)

    @property
    def pages_per_slot(self) -> int:
        return -(-self.max_seq // self.page_size)

    @property
    def page_gb(self) -> float:
        return self.slot_gb / self.pages_per_slot

    def pages_for_tokens(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.page_size)

    def pages_for_slots(self, slots: int) -> int:
        return slots * self.pages_per_slot

    def gb_for_pages(self, pages: int) -> float:
        return self.slot_gb * (pages / self.pages_per_slot)

    def split(self, layer_counts: Sequence[int]) -> Tuple["PageAccounting", ...]:
        """Per-pipeline-stage grants: a stage serving ``n_k`` of the range's
        ``L`` layers holds ``slot_gb * n_k / L`` of the slot's cache bytes.

        Conservation is exact *by construction*, not by rounding luck: the
        last stage takes the residual ``slot_gb - sum(earlier grants)``
        (nudged by ulps against float double-rounding), so summing the
        grants left-to-right reproduces the paper's ``s_c`` bit-for-bit —
        the control-plane contract survives sharding the cache over stages.
        """
        counts = [int(c) for c in layer_counts]
        if not counts or any(c <= 0 for c in counts):
            raise ValueError(f"layer counts must be positive, got {layer_counts}")
        L = sum(counts)
        grants: List[float] = [self.slot_gb * (c / L) for c in counts[:-1]]
        acc = 0.0
        for g in grants:
            acc += g
        last = self.slot_gb - acc
        for _ in range(4):          # double-rounding guard (at most 1-2 ulps)
            total = acc + last
            if total == self.slot_gb:
                break
            last = math.nextafter(
                last, -math.inf if total > self.slot_gb else math.inf)
        if acc + last != self.slot_gb:
            raise AssertionError("stage grant residual failed to close")
        grants.append(last)
        return tuple(dataclasses.replace(self, slot_gb=g) for g in grants)


class PagedCache:
    """Paged KV cache: pooled fixed-size token pages + per-slot block tables.

    Every cache leaf whose axis 2 scales with ``max_seq`` (full-attention
    K/V, MLA latent, window>=max_seq SWA rings) is stored as one pooled
    buffer ``(layers, total_pages + 1, page_size, *tail)`` — the final page
    is write-only scratch absorbing bucketed-prefill padding.  Leaves that do
    not scale with ``max_seq`` (recurrent/SSM state, window<max_seq rings)
    stay slot-resident as ``(layers, num_slots, *tail)``.

    Host-side state (numpy, no device sync): a ``(num_slots,
    pages_per_slot)`` block table, a LIFO free-page stack, per-slot lengths.
    All device writes go through jitted functions with donated pool buffers,
    so admission costs O(prompt) and a decode write costs O(active) — never
    O(pool).  Freed pages are returned unzeroed: stale contents are masked
    by lengths and fully overwritten by the next prefill into the page.
    """

    def __init__(self, model: Model, num_slots: int, max_seq: int,
                 page_size: int = PAGE_SIZE,
                 total_pages: Optional[int] = None,
                 device=None, materialize: bool = True):
        if page_size < 1 or (page_size & (page_size - 1)):
            raise ValueError(f"page_size must be a power of two, got {page_size}")
        if max_seq % page_size:
            raise ValueError(
                f"max_seq {max_seq} must be a multiple of page_size {page_size}")
        self.model = model
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.page_size = page_size
        self.pages_per_slot = -(-max_seq // page_size)
        if total_pages is None:
            total_pages = num_slots * self.pages_per_slot
        if total_pages < self.pages_per_slot:
            raise ValueError(
                f"total_pages={total_pages} cannot hold one full sequence "
                f"({self.pages_per_slot} pages)")
        self.total_pages = total_pages
        self.scratch_page = total_pages          # index of the write-only page

        # Classify leaves by probing init_cache at two sequence lengths:
        # a leaf is paged iff its axis 2 tracks max_seq.  (window<max_seq SWA
        # rings keep shape min(window, S) = window at both probes -> resident.)
        probe = model.cache_specs(1, max_seq)
        probe2 = model.cache_specs(1, max_seq + page_size)
        flat, self._treedef = jax.tree_util.tree_flatten(probe)
        flat2, _ = jax.tree_util.tree_flatten(probe2)
        self._paged: Tuple[bool, ...] = tuple(
            len(a.shape) > 2 and a.shape[2] == max_seq
            and a.shape[2] != b.shape[2]
            for a, b in zip(flat, flat2))
        self._one_specs = flat
        self.device = device
        if materialize:
            self.leaves: List[jnp.ndarray] = []
            for spec, paged in zip(flat, self._paged):
                if paged:
                    shape = (spec.shape[0], total_pages + 1, page_size,
                             *spec.shape[3:])
                else:
                    shape = (spec.shape[0], num_slots, *spec.shape[2:])
                leaf = jnp.zeros(shape, spec.dtype)
                if device is not None:
                    leaf = jax.device_put(leaf, device)
                self.leaves.append(leaf)
        else:
            # accounting-only master: block table / free stack / lengths
            # without pool buffers (the leaves live in leaf_range views)
            self.leaves = None

        self.block_table = np.full((num_slots, self.pages_per_slot), -1,
                                   np.int32)
        self.pages_used = np.zeros((num_slots,), np.int32)
        self.lengths = np.zeros((num_slots,), np.int32)
        self.free: List[int] = list(range(num_slots))
        self._active: set = set()
        self._free_pages: List[int] = list(range(total_pages))
        self._write_jit = jax.jit(self._write_impl, donate_argnums=(0,))

    def leaf_range(self, model_slice, device=None) -> "PagedCache":
        """A pipeline-stage view: its own device-resident pool buffers for
        ``model_slice``'s layer range, sharing this cache's page accounting
        (block table, free-page stack, per-slot lengths, slot free list)
        *by reference*.  Page ids are global, so one ``decode_view`` from
        the master indexes every stage's pool identically, and the sum of
        per-stage memory grants is the master's grant exactly (see
        :meth:`PageAccounting.split`)."""
        view = PagedCache(model_slice, self.num_slots, self.max_seq,
                          page_size=self.page_size,
                          total_pages=self.total_pages, device=device)
        view.block_table = self.block_table
        view.pages_used = self.pages_used
        view.lengths = self.lengths
        view.free = self.free
        view._active = self._active
        view._free_pages = self._free_pages
        return view

    # -- accounting ------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free_pages)

    @property
    def active_slots(self) -> List[int]:
        return sorted(self._active)

    @property
    def num_active(self) -> int:
        return len(self._active)

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.page_size)

    # -- slot lifecycle --------------------------------------------------------
    def can_admit(self, true_len: int) -> bool:
        """A free slot plus pages covering the prompt *and* its first decode
        write (``true_len + 1`` tokens) — admissions that would immediately
        preempt are refused up front."""
        return bool(self.free) and \
            len(self._free_pages) >= self.pages_for(true_len + 1)

    def acquire(self, true_len: int) -> Optional[int]:
        if not self.can_admit(true_len):
            return None
        slot = self.free.pop()
        self._active.add(slot)
        need = self.pages_for(true_len)
        for i in range(need):
            self.block_table[slot, i] = self._free_pages.pop()
        self.pages_used[slot] = need
        self.lengths[slot] = 0
        return slot

    def release(self, slot: int) -> None:
        used = int(self.pages_used[slot])
        # reversed: the stack hands pages back out lowest-allocated-first,
        # keeping page reuse deterministic for the parity tests
        for i in reversed(range(used)):
            self._free_pages.append(int(self.block_table[slot, i]))
        self.block_table[slot, :used] = -1
        self.pages_used[slot] = 0
        self.lengths[slot] = 0
        self._active.discard(slot)
        self.free.append(slot)

    def ensure_decode_write(self, slot: int) -> bool:
        """Guarantee the page holding this slot's next write position exists,
        allocating on demand.  False = pool exhausted (caller preempts)."""
        pos = int(self.lengths[slot])
        pg = pos // self.page_size
        if pg < int(self.pages_used[slot]):
            return True
        if not self._free_pages:
            return False
        self.block_table[slot, pg] = self._free_pages.pop()
        self.pages_used[slot] = pg + 1
        return True

    # -- prefill ---------------------------------------------------------------
    def prefill_buffer(self, pad_len: int) -> Any:
        """A batch-1 cache pytree sized for a ``pad_len``-token prefill:
        paged leaves truncated to ``pad_len`` positions, resident leaves at
        their full shapes (prefill logits and written K/V are identical to a
        full-``max_seq`` buffer — masked positions contribute exact zeros)."""
        if pad_len % self.page_size:
            raise ValueError(
                f"pad_len {pad_len} must be a multiple of page_size "
                f"{self.page_size}")
        leaves = []
        for spec, paged in zip(self._one_specs, self._paged):
            shape = (spec.shape[0], 1, pad_len, *spec.shape[3:]) if paged \
                else spec.shape
            leaves.append(jnp.zeros(shape, spec.dtype))
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    def _write_impl(self, leaves, one_leaves, ids, slot):
        out = []
        for leaf, one, paged in zip(leaves, one_leaves, self._paged):
            src = one[:, 0]
            if paged:
                n = ids.shape[0]
                src = src.reshape(leaf.shape[0], n, self.page_size,
                                  *leaf.shape[3:])
                out.append(leaf.at[:, ids].set(src))
            else:
                out.append(leaf.at[:, slot].set(src))
        return out

    def write_prefill(self, slot: int, cache_one: Any, true_len: int) -> None:
        """Scatter a batch-1 prefilled cache (from :meth:`prefill_buffer`)
        into this slot's pages + resident row.  Chunks beyond the slot's
        allocated pages (bucketed-prefill padding) land in the scratch page.
        Cost: O(pad_len), not O(pool) — the pool buffers are donated.  (One
        CPU-only caveat: XLA's CPU emitter lowers bfloat16 scatters through
        a whole-operand float32 round-trip, so bf16 pools pay an O(pool)
        conversion pass on CPU; float32 pools and the TPU target donate
        truly in place.)"""
        one_leaves, treedef = jax.tree_util.tree_flatten(cache_one)
        if treedef != self._treedef:
            raise ValueError("cache_one structure does not match the model cache")
        pads = [one.shape[2]
                for one, paged in zip(one_leaves, self._paged) if paged]
        if pads:
            n_chunks = pads[0] // self.page_size
            n_real = min(self.pages_for(true_len), n_chunks)
            ids = np.full((n_chunks,), self.scratch_page, np.int32)
            ids[:n_real] = self.block_table[slot, :n_real]
        else:
            # resident-only layer range (e.g. a pure-SSM pipeline stage):
            # nothing paged to scatter, slot rows only
            ids = np.zeros((0,), np.int32)
        self.leaves = self._write_jit(
            self.leaves, one_leaves, jnp.asarray(ids),
            jnp.asarray(slot, jnp.int32))
        self.lengths[slot] = true_len

    # -- decode view -----------------------------------------------------------
    def decode_view(self, slots: List[int], nb: int, npg: int
                    ) -> Dict[str, np.ndarray]:
        """Host-side index arrays for a dense decode batch over ``slots``,
        padded to ``nb`` rows (duplicating row 0 — its decode is row-wise
        bit-identical, so duplicate scatters write equal values) and ``npg``
        pages per row (padding with the row's own first page; garbage there
        is masked by lengths)."""
        pad = list(slots) + [slots[0]] * (nb - len(slots))
        page_ids = np.zeros((nb, npg), np.int32)
        slot_idx = np.zeros((nb,), np.int32)
        lengths = np.zeros((nb,), np.int32)
        write_page = np.zeros((nb,), np.int32)
        write_off = np.zeros((nb,), np.int32)
        for i, s in enumerate(pad):
            used = int(self.pages_used[s])
            row = self.block_table[s, :used]
            page_ids[i, :min(used, npg)] = row[:npg]
            page_ids[i, used:] = row[0]
            slot_idx[i] = s
            pos = int(self.lengths[s])
            lengths[i] = pos
            write_page[i] = self.block_table[s, pos // self.page_size]
            write_off[i] = pos % self.page_size
        return {"page_ids": page_ids, "slot_idx": slot_idx,
                "lengths": lengths, "write_page": write_page,
                "write_off": write_off}
