"""Bridge between model configs and the paper's (s_m, s_c) service spec, plus
the slotted batched KV cache used by chain engines.

The paper's memory model:  server memory = s_m * (#blocks) + s_c * (cache
slots in use).  For a transformer served at max sequence length S_max with
TP degree t:  s_m = per-layer weight bytes / t;  s_c = per-layer KV bytes per
token * S_max / t (static allocation, Section 2.1.2).  For recurrent layers
(xLSTM / SSM) the "KV" is the recurrent state: size independent of S_max —
the chain-composition algorithms are unchanged (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.servers import Server, ServiceSpec
from repro.models import Model
from repro.models.transformer import stages


GIB = 1024.0 ** 3


def recurrent_state_bytes(cfg: ModelConfig, bytes_per_el: int = 4) -> float:
    """Per-request per-layer recurrent-state bytes (mLSTM/sLSTM/SSM)."""
    if cfg.family == "ssm":
        H, hd = cfg.num_heads, cfg.hd
        mlstm = (H * hd * hd + H * hd) * bytes_per_el
        slstm = 4 * cfg.d_model * bytes_per_el
        return max(mlstm, slstm)
    if cfg.family == "hybrid":
        d_inner = cfg.ssm.expand * cfg.d_model
        return d_inner * cfg.ssm.state_dim * bytes_per_el
    return 0.0


def service_spec_for(
    cfg: ModelConfig, max_seq: int, tp_degree: int = 1, bytes_per_el: int = 2,
) -> ServiceSpec:
    """The paper's (L, s_m, s_c) for serving ``cfg`` at ``max_seq``."""
    s_m = cfg.block_bytes(bytes_per_el) / tp_degree / GIB
    kv = cfg.kv_bytes_per_token_per_layer(bytes_per_el) * max_seq
    if cfg.family == "hybrid":
        # SWA layers cache only the window; global layers the full context.
        n_glob = len(cfg.global_attn_layers)
        frac = (n_glob + (cfg.num_layers - n_glob)
                * min(cfg.window, max_seq) / max_seq) / cfg.num_layers
        kv = kv * frac
    if cfg.family == "ssm":
        kv = 0.0
    kv += recurrent_state_bytes(cfg)
    s_c = max(kv, 1.0) / tp_degree / GIB
    return ServiceSpec(num_blocks=cfg.num_layers, block_size_gb=s_m,
                       cache_size_gb=max(s_c, 1e-9))


def tau_estimates(
    cfg: ModelConfig,
    mean_in_tokens: float,
    mean_out_tokens: float,
    tflops: float = 197.0,
    hbm_gb_per_ms: float = 0.819,
    chips: int = 16,
    overhead_ms: float = 1.0,
) -> float:
    """tau_j^p per the paper's footnote 11: prefill is compute-bound
    (t_I = FLOPs-per-block-per-token / peak), decode memory-bound
    (t_O = block bytes / HBM bandwidth).  Returns seconds per block per job."""
    n_active = cfg.active_layer_param_count()
    flops_per_tok = 2 * n_active
    t_in = flops_per_tok / (tflops * 1e9) / chips            # ms per token
    t_out = cfg.block_bytes() / 1e6 / hbm_gb_per_ms / 1e3 / chips   # ms
    tau_ms = overhead_ms + t_in * mean_in_tokens + t_out * max(mean_out_tokens - 1, 0)
    return tau_ms / 1e3


# ---------------------------------------------------------------------------
# Slotted batched cache
# ---------------------------------------------------------------------------

class SlotCache:
    """Capacity-``c`` batched cache for one chain engine.  Slot i of every
    cache leaf (axis 1, after the per-stage layer axis) belongs to request i.
    """

    def __init__(self, model: Model, capacity: int, max_seq: int):
        self.model = model
        self.capacity = capacity
        self.max_seq = max_seq
        self.cache = model.init_cache(capacity, max_seq)
        self.free: List[int] = list(range(capacity))
        self.lengths = np.zeros((capacity,), np.int32)

    def acquire(self) -> Optional[int]:
        if not self.free:
            return None
        return self.free.pop()

    def release(self, slot: int) -> None:
        self.lengths[slot] = 0
        self.free.append(slot)

    def write_prefill(self, slot: int, cache_one: Any, prompt_len: int) -> None:
        """Insert a batch-1 prefilled cache into slot ``slot``."""
        self.cache = jax.tree.map(
            lambda full, one: full.at[:, slot].set(one[:, 0]), self.cache, cache_one)
        self.lengths[slot] = prompt_len

    @property
    def active_slots(self) -> List[int]:
        return [i for i in range(self.capacity) if i not in self.free]
