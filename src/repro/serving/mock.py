"""Mock data plane: a numpy-only stand-in for ``ChainEngine``.

The orchestrator's control plane — composition, JFFC dispatch, failover,
warm-up, autoscaling hooks — is the paper's contribution; the jax model
underneath is interchangeable.  ``MockEngine`` implements the engine
interface (admit / step / evict_all / slot accounting) with a synthetic
token generator: one token per decode round, exactly like the real engine,
but with no model, no params, no jax — so control-plane tests and the
autoscale benchmark's live-loop leg run in the minimal-dependency
environment and in milliseconds.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.chains import Chain
from repro.core.servers import ServiceSpec

from .orchestrator import Orchestrator, OrchestratorConfig
from .request import Request, State


class MockEngine:
    """Drop-in ``ChainEngine`` with a synthetic one-token-per-step model."""

    def __init__(self, model, params, chain: Chain, capacity: int,
                 max_seq: int):
        self.model = model
        self.params = params
        self.chain = chain
        self.capacity = capacity
        self.max_seq = max_seq
        self.requests: Dict[int, Request] = {}
        self._free: List[int] = list(range(capacity))

    @property
    def has_free_slot(self) -> bool:
        return bool(self._free)

    @property
    def num_active(self) -> int:
        return self.capacity - len(self._free)

    def admit(self, req: Request, now: float = 0.0) -> bool:
        if not self._free:
            return False
        slot = self._free.pop()
        req.slot = slot
        req.state = State.RUNNING
        if req.start_time is None:
            req.start_time = now
        # prefill emits the first token, as the real engine does
        req.output.append(self._next_token(req))
        if req.done:
            req.state = State.DONE
            req.finish_time = now
            self._free.append(slot)
            return True
        self.requests[slot] = req
        return True

    def step(self, now: float = 0.0) -> List[Request]:
        finished: List[Request] = []
        for slot, req in list(self.requests.items()):
            req.output.append(self._next_token(req))
            if req.done:
                req.state = State.DONE
                req.finish_time = now
                finished.append(req)
                del self.requests[slot]
                self._free.append(slot)
        return finished

    def evict_all(self) -> List[Request]:
        out = []
        for slot, req in list(self.requests.items()):
            req.state = State.QUEUED
            req.slot = None
            req.chain_idx = None
            req.retries += 1
            out.append(req)
            self._free.append(slot)
        self.requests.clear()
        return out

    @staticmethod
    def _next_token(req: Request) -> int:
        # deterministic, eos-avoiding synthetic token
        tok = (len(req.output) + 1) % 50_000
        if req.eos_token is not None and tok == req.eos_token:
            tok += 1
        return tok


def mock_orchestrator(
    servers,
    spec: ServiceSpec,
    arrival_rate: float,
    config: Optional[OrchestratorConfig] = None,
    classes=None,
    aging_rate: Optional[float] = None,
) -> Orchestrator:
    """An ``Orchestrator`` over the mock data plane (no model, no jax).

    ``classes`` / ``aging_rate`` are conveniences for multi-tenant
    control-plane tests: they override the corresponding
    :class:`OrchestratorConfig` fields without constructing a config.
    """
    cfg = config if config is not None else OrchestratorConfig()
    if cfg.engine_factory is None:
        cfg = dataclasses.replace(cfg, engine_factory=MockEngine)
    if classes is not None:
        cfg = dataclasses.replace(cfg, classes=tuple(classes))
    if aging_rate is not None:
        cfg = dataclasses.replace(cfg, aging_rate=aging_rate)
    return Orchestrator(servers, spec, model=None, params=None,
                        arrival_rate=arrival_rate, config=cfg)
