"""Serving orchestrator: the paper's control plane running a live system.

Two time scales, exactly as in Section 2.2:
  * offline (seconds, on composition events): tune c (Thm 3.7 lower bound),
    GBP-CR placement, GCA cache allocation -> chain engines;
  * online (per request): JFFC dispatch (Alg. 3) with a central FIFO queue.

Fault tolerance / elasticity (DESIGN.md §7):
  * ``fail_server``   — retire chains traversing the dead server, re-queue
    their in-flight requests (context preserved — prompt + generated tokens
    re-prefill on the new chain), recompose on survivors.
  * ``add_server``    — recompose including the newcomer.
  * ``report_tau``    — per-server EWMA latency feedback; when drift exceeds
    a threshold the next recomposition demotes stragglers (the paper's
    "fast with fast" principle applied online).
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import (
    Allocation,
    Server,
    ServiceSpec,
    compose,
    gbp_cr,
    gca,
)
from repro.models import Model
from .engine import ChainEngine
from .request import Request, State


@dataclasses.dataclass
class OrchestratorConfig:
    rho_bar: float = 0.7
    tuner: str = "bound-lower"
    max_seq: int = 256
    ewma_alpha: float = 0.2
    straggler_threshold: float = 1.5     # tau drift ratio triggering recompose
    max_retries: int = 3


class Orchestrator:
    def __init__(
        self,
        servers: Sequence[Server],
        spec: ServiceSpec,
        model: Model,
        params,
        arrival_rate: float,
        config: OrchestratorConfig = OrchestratorConfig(),
    ):
        self.spec = spec
        self.model = model
        self.params = params
        self.lam = arrival_rate
        self.cfg = config
        self.servers: Dict[str, Server] = {s.sid: s for s in servers}
        self.tau_scale: Dict[str, float] = {s.sid: 1.0 for s in servers}
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        self.failed: List[Request] = []
        self.engines: List[ChainEngine] = []
        self.allocation: Optional[Allocation] = None
        self.c_star: int = 1
        self.recompositions = 0
        self._compose()

    # -- composition (offline time scale) ---------------------------------------
    def _effective_servers(self) -> List[Server]:
        out = []
        for sid, s in self.servers.items():
            scale = self.tau_scale[sid]
            out.append(Server(sid, s.memory_gb, s.tau_c * scale, s.tau_p * scale))
        return out

    def _compose(self) -> None:
        servers = self._effective_servers()
        if not servers:
            self.engines = []
            self.allocation = None
            return
        self.c_star, placement, alloc = compose(
            servers, self.spec, self.lam, self.cfg.rho_bar, tuner=self.cfg.tuner)
        self.allocation = alloc
        pairs = alloc.sorted_by_rate()
        self.engines = [
            ChainEngine(self.model, self.params, chain, cap, self.cfg.max_seq)
            for chain, cap in pairs
        ]
        self.recompositions += 1

    # -- dispatch (online time scale; Alg. 3) -------------------------------------
    def submit(self, req: Request, now: float = 0.0) -> None:
        if not self._dispatch(req, now):
            self.queue.append(req)

    def _dispatch(self, req: Request, now: float) -> bool:
        # engines are sorted fastest-first; JFFC = first with a free slot.
        for idx, eng in enumerate(self.engines):
            if eng.has_free_slot:
                ok = eng.admit(req, now)
                if ok:
                    req.chain_idx = idx
                    if req.state == State.DONE:
                        self.finished.append(req)
                    return True
        return False

    def step(self, now: float = 0.0) -> List[Request]:
        """One decode round across all engines + queue pulls (Alg. 3 line 6)."""
        done: List[Request] = []
        for eng in self.engines:
            for req in eng.step(now):
                done.append(req)
                # a completion frees a slot on THIS chain; pull the queue head
                if self.queue:
                    nxt = self.queue.popleft()
                    if eng.admit(nxt, now):
                        if nxt.state == State.DONE:
                            done.append(nxt)
                    else:   # capacity race: put it back
                        self.queue.appendleft(nxt)
        self.finished.extend(done)
        return done

    def drain(self, now_fn=None, max_rounds: int = 100_000) -> None:
        """Run decode rounds until queue + engines are empty."""
        rounds = 0
        t = 0.0
        while (self.queue or any(e.requests for e in self.engines)) \
                and rounds < max_rounds:
            t = now_fn() if now_fn else t + 1.0
            self.step(t)
            # JFFC also admits from the queue whenever capacity is free
            while self.queue:
                req = self.queue[0]
                if not self._dispatch(req, t):
                    break
                self.queue.popleft()
            rounds += 1

    # -- fault tolerance / elasticity ---------------------------------------------
    def fail_server(self, sid: str, now: float = 0.0) -> int:
        """Remove a dead server; re-queue affected in-flight requests."""
        if sid not in self.servers:
            raise KeyError(sid)
        del self.servers[sid]
        del self.tau_scale[sid]
        requeued = 0
        survivors: List[Request] = []
        for eng in self.engines:
            if sid in eng.chain.servers:
                for req in eng.evict_all():
                    if req.retries > self.cfg.max_retries:
                        req.state = State.FAILED
                        self.failed.append(req)
                    else:
                        survivors.append(req)
                        requeued += 1
        # Recompose on the surviving set, preserving untouched engines' caches
        # is possible when their chains survive verbatim; for simplicity and
        # correctness we re-admit only evicted requests and rebuild engines
        # whose chains changed.
        self._recompose_preserving(now)
        for req in survivors:
            self.submit(req, now)
        return requeued

    def add_server(self, server: Server, now: float = 0.0) -> None:
        self.servers[server.sid] = server
        self.tau_scale[server.sid] = 1.0
        self._recompose_preserving(now)

    def _recompose_preserving(self, now: float) -> None:
        """Recompose; engines whose (chain, capacity) survive keep their KV
        caches and in-flight requests, others evict to the queue."""
        old = {tuple(e.chain.servers): e for e in self.engines}
        evicted: List[Request] = []
        self._compose()
        new_engines: List[ChainEngine] = []
        for eng in self.engines:
            key = tuple(eng.chain.servers)
            prev = old.pop(key, None)
            if prev is not None and prev.capacity == eng.capacity:
                new_engines.append(prev)     # cache + requests preserved
            else:
                new_engines.append(eng)
                if prev is not None:
                    evicted.extend(prev.evict_all())
        for leftover in old.values():
            evicted.extend(leftover.evict_all())
        self.engines = new_engines
        for req in evicted:
            self.submit(req, now)

    def report_tau(self, sid: str, observed_scale: float, now: float = 0.0) -> None:
        """EWMA straggler feedback: observed_scale = measured/nominal time."""
        if sid not in self.tau_scale:
            return
        a = self.cfg.ewma_alpha
        self.tau_scale[sid] = (1 - a) * self.tau_scale[sid] + a * observed_scale
        if self.tau_scale[sid] > self.cfg.straggler_threshold:
            self._recompose_preserving(now)

    # -- scenario hooks (repro.core.scenarios timelines on a live system) ----------
    def apply_scenario_event(self, ev, now: float = 0.0) -> dict:
        """Apply one ``repro.core.scenarios.ScenarioEvent`` to the live
        system: ``fail`` -> :meth:`fail_server`, ``add`` ->
        :meth:`add_server`, ``slowdown`` -> :meth:`report_tau` (the scale is
        fed as the observed straggler ratio).  ``burst`` events shape the
        request arrival process, not the cluster, and are a no-op here."""
        out = {"time": ev.time, "kind": ev.kind, "requeued": 0}
        if ev.kind == "fail":
            if ev.sid in self.servers:
                out["requeued"] = self.fail_server(ev.sid, now)
        elif ev.kind == "add":
            self.add_server(ev.server, now)
        elif ev.kind == "slowdown":
            self.report_tau(ev.sid, ev.scale, now)
        out["chains"] = len(self.engines)
        return out

    def run_scenario(
        self,
        scenario,
        requests: Sequence,
        dt: float = 1.0,
        max_rounds: int = 100_000,
    ) -> dict:
        """Drive decode rounds while firing the scenario's cluster events.

        ``requests`` is a list of ``Request`` (all submitted at t=0) or of
        ``(time, Request)`` pairs.  Each round advances time by ``dt``,
        applies due events, submits due requests, steps every engine, and
        re-admits from the queue.  Returns a summary with the applied-event
        log merged into :meth:`stats`.
        """
        timed: List[Tuple[float, Request]] = []
        for item in requests:
            if isinstance(item, Request):
                timed.append((0.0, item))
            else:
                timed.append((float(item[0]), item[1]))
        timed.sort(key=lambda p: p[0])
        pending = deque(scenario.cluster_events())
        applied: List[dict] = []
        next_req = 0
        rounds = 0
        t = 0.0
        while rounds < max_rounds:
            t = rounds * dt
            while pending and pending[0].time <= t:
                applied.append(self.apply_scenario_event(pending.popleft(), t))
            while next_req < len(timed) and timed[next_req][0] <= t:
                self.submit(timed[next_req][1], t)
                next_req += 1
            self.step(t)
            while self.queue:                    # admit whenever capacity frees
                if not self._dispatch(self.queue[0], t):
                    break
                self.queue.popleft()
            rounds += 1
            if (next_req >= len(timed) and not pending and not self.queue
                    and not any(e.requests for e in self.engines)):
                break
        return {"rounds": rounds, "events": applied, **self.stats()}

    # -- introspection ---------------------------------------------------------------
    def stats(self) -> dict:
        rts = [r.response_time() for r in self.finished if r.response_time() is not None]
        return {
            "finished": len(self.finished),
            "failed": len(self.failed),
            "queued": len(self.queue),
            "active": sum(e.num_active for e in self.engines),
            "chains": [(list(e.chain.servers), e.capacity) for e in self.engines],
            "c_star": self.c_star,
            "recompositions": self.recompositions,
            "mean_response": float(np.mean(rts)) if rts else math.nan,
        }
