"""Serving orchestrator: the paper's control plane running a live system.

Two time scales, exactly as in Section 2.2:
  * offline (seconds, on composition events): tune c (Thm 3.7 lower bound),
    GBP-CR placement, GCA cache allocation -> chain engines;
  * online (per request): JFFC dispatch (Alg. 3) with a central FIFO queue.

Fault tolerance / elasticity (DESIGN.md §7):
  * ``fail_server``   — retire chains traversing the dead server, re-queue
    their in-flight requests (context preserved — prompt + generated tokens
    re-prefill on the new chain), recompose on survivors.
  * ``fail_servers``  — correlated group failure (a rack): one eviction +
    recomposition pass for the whole set.
  * ``add_server``    — recompose including the newcomer; with a
    ``warmup_until`` deadline the server is *placed* (tracked, billed) but
    excluded from the composition — no dispatches — until it is warm.
  * ``report_tau``    — per-server EWMA latency feedback; when drift exceeds
    a threshold the next recomposition demotes stragglers (the paper's
    "fast with fast" principle applied online).

Autoscaling (``repro.autoscale``) observes and actuates through hooks:
``submit_hooks`` fire on every request submission (arrival telemetry),
``step_hooks`` after every decode round (state sampling + control ticks).
The module is importable without jax — the default ``ChainEngine`` data
plane is imported lazily; ``OrchestratorConfig.engine_factory`` swaps in a
numpy-only mock (``repro.serving.mock.MockEngine``) for control-plane tests
and benchmarks in minimal environments.

Multi-tenant SLO classes: requests carry a class index into
``OrchestratorConfig.classes`` (:class:`repro.core.RequestClass`).  The
central queue is ordered by aged class priority (tier + aging * arrival —
FIFO with a single default class), and submissions of sheddable classes
(finite deadline) pass an **admission gate**: when the estimated queueing
wait exceeds the class deadline (scaled by ``admission_level``, the
autoscaler's throttle), the request is *deferred* — parked without a slot
and readmitted once the backlog drains, so best-effort work yields to
interactive work instead of forcing a scale-out.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import (
    Allocation,
    DEFAULT_CLASS,
    RequestClass,
    Server,
    ServiceSpec,
    compose_best_effort,
)
from .request import Request, State


@dataclasses.dataclass
class OrchestratorConfig:
    rho_bar: float = 0.7
    tuner: str = "bound-lower"
    max_seq: int = 256
    ewma_alpha: float = 0.2
    straggler_threshold: float = 1.5     # tau drift ratio triggering recompose
    max_retries: int = 3
    # data-plane constructor (model, params, chain, capacity, max_seq) ->
    # engine; None = the jax ChainEngine (imported lazily)
    engine_factory: Optional[Callable] = None
    # multi-tenant SLO classes: request.cls indexes this list; None = the
    # single default class (class-blind FIFO behavior, bit-compatible)
    classes: Optional[Sequence[RequestClass]] = None
    aging_rate: float = 0.0              # priority aging (anti-starvation)


class _PriorityQueue:
    """Central request queue ordered by aged class priority.

    Key = ``(tier + aging * arrival, seq)`` — the static form of the aged
    priority ``tier - aging * waited`` (see ``core.load_balance``), with the
    push sequence as tie-break.  A single tier-0 class with no aging
    degenerates to exact FIFO, preserving the class-blind orchestrator's
    scheduling order.
    """

    def __init__(self, classes: Sequence[RequestClass], aging_rate: float):
        self._classes = list(classes)
        self._aging = float(aging_rate)
        self._heap: List[Tuple[float, int, Request]] = []
        self._seq = 0

    def _kappa(self, req: Request) -> float:
        tier = self._classes[req.cls].priority \
            if 0 <= req.cls < len(self._classes) else 0
        return tier + self._aging * req.arrival_time

    def push(self, req: Request) -> None:
        heapq.heappush(self._heap, (self._kappa(req), self._seq, req))
        self._seq += 1

    def pop(self) -> Request:
        return heapq.heappop(self._heap)[2]

    def peek(self) -> Request:
        return self._heap[0][2]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def __iter__(self):
        return (entry[2] for entry in sorted(self._heap, key=lambda e: e[:2]))


class Orchestrator:
    def __init__(
        self,
        servers: Sequence[Server],
        spec: ServiceSpec,
        model,
        params,
        arrival_rate: float,
        config: OrchestratorConfig = OrchestratorConfig(),
    ):
        self.spec = spec
        self.model = model
        self.params = params
        self.lam = arrival_rate
        self.cfg = config
        self.servers: Dict[str, Server] = {s.sid: s for s in servers}
        self.tau_scale: Dict[str, float] = {s.sid: 1.0 for s in servers}
        self.warming: Dict[str, float] = {}   # sid -> warm-at deadline
        self.classes: List[RequestClass] = (
            list(config.classes) if config.classes else [DEFAULT_CLASS])
        self.queue = _PriorityQueue(self.classes, config.aging_rate)
        self.deferred: Deque[Request] = deque()   # admission-gated parking
        self.admission_level = 1.0
        self.finished: List[Request] = []
        self.failed: List[Request] = []
        self.engines: List = []
        self.draining: List = []   # retired engines finishing committed work
        self.allocation: Optional[Allocation] = None
        self.c_star: int = 1
        self.recompositions = 0
        self.degraded = False                # last composition fell back to c=1
        # autoscale observation points: (req, now) on submit, (self, now)
        # after every decode round
        self.submit_hooks: List[Callable] = []
        self.step_hooks: List[Callable] = []
        # optional repro.obs.MetricsRegistry; publication happens at round
        # granularity in step(), never inside the engines' decode loops
        self.metrics = None
        self._compose()

    # -- composition (offline time scale) ---------------------------------------
    def _engine_factory(self) -> Callable:
        if self.cfg.engine_factory is not None:
            return self.cfg.engine_factory
        from .engine import ChainEngine   # lazy: pulls in jax
        return ChainEngine

    def _effective_servers(self) -> List[Server]:
        out = []
        for sid, s in self.servers.items():
            if sid in self.warming:        # placed, not serving yet
                continue
            scale = self.tau_scale[sid]
            out.append(Server(sid, s.memory_gb, s.tau_c * scale, s.tau_p * scale))
        return out

    def _compose(self) -> None:
        servers = self._effective_servers()
        if not servers:
            self.engines = []
            self.allocation = None
            return
        # both planes degrade through the same helper: largest feasible
        # load under overload, c=1 everything-chain as the last resort
        self.c_star, alloc, self.degraded = compose_best_effort(
            servers, self.spec, self.lam, self.cfg.rho_bar,
            tuner=self.cfg.tuner)
        self.allocation = alloc
        factory = self._engine_factory()
        pairs = alloc.sorted_by_rate()
        self.engines = [
            factory(self.model, self.params, chain, cap, self.cfg.max_seq)
            for chain, cap in pairs
        ]
        self.recompositions += 1

    # -- dispatch (online time scale; Alg. 3) -------------------------------------
    def set_admission_level(self, level: float) -> None:
        """Autoscaler throttle: scales every sheddable class's deadline
        (1.0 = nominal, 0.0 = defer all best-effort work that would queue)."""
        self.admission_level = max(0.0, float(level))

    def _should_defer(self, req: Request) -> bool:
        """Admission gate: defer a sheddable request whose estimated
        queueing wait exceeds its class deadline (scaled by the throttle).
        Never fires when a slot is free (work conservation) — callers try
        :meth:`_dispatch` first."""
        rc = self.classes[req.cls] if 0 <= req.cls < len(self.classes) \
            else DEFAULT_CLASS
        if not rc.sheddable:
            return False
        rate = self.allocation.total_rate if self.allocation is not None \
            else 0.0
        est = (len(self.queue) + 1) / rate if rate > 0 else math.inf
        return est > rc.deadline * self.admission_level

    def submit(self, req: Request, now: float = 0.0) -> None:
        for hook in self.submit_hooks:
            hook(req, now)
        if self._dispatch(req, now):
            return
        if self._should_defer(req):
            req.state = State.DEFERRED
            self.deferred.append(req)
            return
        self.queue.push(req)

    def _resubmit(self, req: Request, now: float) -> None:
        """Re-dispatch an evicted/requeued request WITHOUT firing the submit
        hooks or the admission gate — a requeue is not a new arrival
        (counting it as one would feed phantom load into the autoscaler's
        rate estimate right when the cluster is already recomposing), and
        work already admitted is never shed."""
        if not self._dispatch(req, now):
            self.queue.push(req)

    def _readmit_deferred(self, now: float) -> None:
        """Pull deferred best-effort work back in once the backlog drains
        below its admission threshold (oldest first).  Deferred work never
        jumps the queue: freed capacity goes to queued requests first —
        direct dispatch only when the queue is empty, otherwise readmission
        means joining the priority queue at the back of its tier."""
        while self.deferred:
            req = self.deferred[0]
            if not self.queue and self._dispatch(req, now):
                self.deferred.popleft()
                continue
            if not self._should_defer(req):
                req.state = State.QUEUED
                self.queue.push(self.deferred.popleft())
                continue
            break

    def _dispatch(self, req: Request, now: float) -> bool:
        # engines are sorted fastest-first; JFFC = first with a free slot.
        for idx, eng in enumerate(self.engines):
            if eng.has_free_slot:
                ok = eng.admit(req, now)
                if ok:
                    req.chain_idx = idx
                    if req.state == State.DONE:
                        self.finished.append(req)
                    return True
        return False

    def step(self, now: float = 0.0) -> List[Request]:
        """One decode round across all engines + queue pulls (Alg. 3 line 6)."""
        self._expire_warming(now)
        done: List[Request] = []
        for eng in self.engines:
            for req in eng.step(now):
                done.append(req)
                # a completion frees a slot on THIS chain; pull the
                # highest-priority queued request (FIFO with one class)
                if self.queue:
                    nxt = self.queue.peek()
                    if eng.admit(nxt, now):
                        self.queue.pop()
                        if nxt.state == State.DONE:
                            done.append(nxt)
        # retired engines finish their committed requests (no new admits)
        for eng in list(self.draining):
            done.extend(eng.step(now))
            if not eng.requests:
                self.draining.remove(eng)
        # paged engines may have preempted requests on page exhaustion;
        # resubmit them (context preserved — they re-prefill with their
        # generated tokens) unless they are out of retries
        for eng in list(self.engines) + list(self.draining):
            take = getattr(eng, "take_preempted", None)
            if take is None:
                continue
            for req in take():
                if req.retries > self.cfg.max_retries:
                    req.state = State.FAILED
                    self.failed.append(req)
                else:
                    self._resubmit(req, now)
        self.finished.extend(done)
        self._readmit_deferred(now)
        if self.metrics is not None:
            m = self.metrics
            m.counter("orch.rounds").inc()
            m.counter("orch.completions").inc(len(done))
            m.gauge("orch.queue_len").set(len(self.queue))
            m.gauge("orch.deferred").set(len(self.deferred))
            self._publish_engine_gauges()
            h = m.histogram("orch.response_s")
            for req in done:
                rt = req.response_time()
                if rt is not None:
                    h.record(rt)
        for hook in self.step_hooks:
            hook(self, now)
        return done

    def _publish_engine_gauges(self) -> None:
        """Data-plane gauges, round-granularity only (the PR 7 zero-hot-loop
        contract): active slots, free pages across paged engines, per-engine
        batch occupancy, live prefill-jit specializations.  Called from
        :meth:`step` *and* from every eviction / preemption / recomposition
        path — a page freed by ``evict_all`` must show up in
        ``orch.free_pages`` without waiting for the next decode round, or
        traces read as phantom page leaks."""
        if self.metrics is None:
            return
        m = self.metrics
        m.gauge("orch.active_slots").set(
            sum(e.num_active for e in self.engines))
        pages = [e.free_pages for e in self.engines
                 if hasattr(e, "free_pages")]
        if pages:
            m.gauge("orch.free_pages").set(sum(pages))
        m.gauge("orch.prefill_buckets").set(
            sum(getattr(e, "prefill_bucket_count", 0)
                for e in self.engines))
        occ = m.histogram("orch.batch_occupancy")
        for e in self.engines:
            if e.capacity:
                occ.record(e.num_active / e.capacity)

    def drain(self, now_fn=None, max_rounds: int = 100_000) -> None:
        """Run decode rounds until queue + deferred + engines are empty."""
        rounds = 0
        t = 0.0
        while (self.queue or self.deferred or self.draining
               or any(e.requests for e in self.engines)) \
                and rounds < max_rounds:
            t = now_fn() if now_fn else t + 1.0
            self.step(t)
            # JFFC also admits from the queue whenever capacity is free
            while self.queue:
                req = self.queue.peek()
                if not self._dispatch(req, t):
                    break
                self.queue.pop()
            rounds += 1

    # -- fault tolerance / elasticity ---------------------------------------------
    def fail_server(self, sid: str, now: float = 0.0) -> int:
        """Remove a dead server; re-queue affected in-flight requests."""
        return self.fail_servers([sid], now)

    def fail_servers(self, sids: Sequence[str], now: float = 0.0) -> int:
        """Correlated failure (a rack, a power domain): remove the whole set
        with a single eviction + recomposition pass."""
        dead = set(sids)
        missing = dead - set(self.servers)
        if missing:
            raise KeyError(sorted(missing)[0])
        for sid in dead:
            del self.servers[sid]
            del self.tau_scale[sid]
            self.warming.pop(sid, None)
        requeued = 0
        survivors: List[Request] = []
        # draining engines die with their hardware too — a retired chain
        # that was gracefully finishing its work loses it when a server it
        # traverses actually fails
        doomed_draining = [e for e in self.draining
                           if dead & set(e.chain.servers)]
        for eng in doomed_draining:
            self.draining.remove(eng)
        for eng in list(self.engines) + doomed_draining:
            if dead & set(eng.chain.servers):
                for req in eng.evict_all():
                    if req.retries > self.cfg.max_retries:
                        req.state = State.FAILED
                        self.failed.append(req)
                    else:
                        survivors.append(req)
                        requeued += 1
        # Recompose on the surviving set.  Engines whose chains survive
        # verbatim keep caches + requests; engines displaced only by the new
        # composition (their servers are alive) drain gracefully — only the
        # dead servers' requests pay the re-prefill penalty.
        self._recompose_preserving(now, drain=True)
        for req in survivors:
            self._resubmit(req, now)
        self._publish_engine_gauges()
        return requeued

    def add_server(self, server: Server, now: float = 0.0,
                   warmup_until: Optional[float] = None) -> None:
        """Add a server; with ``warmup_until`` in the future it is *placed*
        (visible in ``servers``, billed by the autoscaler) but kept out of
        the composition — zero dispatches touch it — until the deadline
        passes (checked at each decode round)."""
        self.servers[server.sid] = server
        self.tau_scale[server.sid] = 1.0
        if warmup_until is not None and warmup_until > now:
            self.warming[server.sid] = float(warmup_until)
            return
        self._recompose_preserving(now, drain=True)

    def retire_servers(self, sids: Sequence[str], now: float = 0.0) -> int:
        """Graceful scale-in: the opposite of :meth:`fail_servers` — the
        servers leave the cluster but engines traversing them finish their
        committed requests before shutting down.  Returns the number of
        requests left draining."""
        gone = set(sids) & set(self.servers)
        for sid in gone:
            del self.servers[sid]
            del self.tau_scale[sid]
            self.warming.pop(sid, None)
        before = sum(len(e.requests) for e in self.draining)
        self._recompose_preserving(now, drain=True)
        self._publish_engine_gauges()
        return sum(len(e.requests) for e in self.draining) - before

    def _expire_warming(self, now: float) -> None:
        due = [sid for sid, t in self.warming.items() if t <= now]
        if due:
            for sid in due:
                del self.warming[sid]
            self._recompose_preserving(now, drain=True)

    def _recompose_preserving(self, now: float, drain: bool = False) -> None:
        """Recompose; engines whose (chain, capacity) survive keep their KV
        caches and in-flight requests.  Displaced engines either evict their
        requests to the queue (``drain=False`` — involuntary change, the
        requests re-prefill elsewhere) or keep serving them to completion
        without accepting new work (``drain=True`` — voluntary change:
        retune, scale-out, graceful scale-in; the old and new chain sets
        briefly coexist, as in a real engine rollout)."""
        old = {tuple(e.chain.servers): e for e in self.engines}
        evicted: List[Request] = []
        self._compose()
        new_engines: List = []
        for eng in self.engines:
            key = tuple(eng.chain.servers)
            prev = old.pop(key, None)
            if prev is not None and prev.capacity == eng.capacity:
                new_engines.append(prev)     # cache + requests preserved
            else:
                new_engines.append(eng)
                if prev is not None:
                    if drain and prev.requests:
                        self.draining.append(prev)
                    else:
                        evicted.extend(prev.evict_all())
        for leftover in old.values():
            if drain and leftover.requests:
                self.draining.append(leftover)
            else:
                evicted.extend(leftover.evict_all())
        self.engines = new_engines
        for req in evicted:
            self._resubmit(req, now)
        self._publish_engine_gauges()

    def report_tau(self, sid: str, observed_scale: float, now: float = 0.0) -> None:
        """EWMA straggler feedback: observed_scale = measured/nominal time."""
        if sid not in self.tau_scale:
            return
        a = self.cfg.ewma_alpha
        self.tau_scale[sid] = (1 - a) * self.tau_scale[sid] + a * observed_scale
        if self.tau_scale[sid] > self.cfg.straggler_threshold:
            self._recompose_preserving(now, drain=True)

    # -- scenario hooks (repro.core.scenarios timelines on a live system) ----------
    def apply_scenario_event(self, ev, now: float = 0.0) -> dict:
        """Apply one ``repro.core.scenarios.ScenarioEvent`` to the live
        system: ``fail`` -> :meth:`fail_server`, ``fail_group`` ->
        :meth:`fail_servers`, ``add`` -> :meth:`add_server`, ``slowdown`` ->
        :meth:`report_tau` (the scale is fed as the observed straggler
        ratio).  ``burst`` events shape the request arrival process, not the
        cluster, and are a no-op here."""
        out = {"time": ev.time, "kind": ev.kind, "requeued": 0}
        if ev.kind == "fail":
            if ev.sid in self.servers:
                out["requeued"] = self.fail_server(ev.sid, now)
        elif ev.kind == "fail_group":
            present = [sid for sid in ev.sids if sid in self.servers]
            if present:
                out["requeued"] = self.fail_servers(present, now)
        elif ev.kind == "add":
            self.add_server(ev.server, now)
        elif ev.kind == "slowdown":
            self.report_tau(ev.sid, ev.scale, now)
        out["chains"] = len(self.engines)
        return out

    def run_scenario(
        self,
        scenario,
        requests: Sequence,
        dt: float = 1.0,
        max_rounds: int = 100_000,
    ) -> dict:
        """Deprecated compatibility shim — the decode-round drive loop now
        lives in :func:`repro.api.planes.drive_orchestrator` (the live
        plane's executor), which also fast-forwards idle stretches instead
        of spinning ``dt`` at a time.  Declarative runs should build a
        ``repro.api.ExperimentSpec`` and call
        ``repro.api.run(spec, plane="live")``; this method survives for
        callers holding a live orchestrator with their own ``Request``
        objects and returns the same summary dict as before.
        """
        import warnings

        warnings.warn(
            "Orchestrator.run_scenario is deprecated; use repro.api.run("
            "spec, plane='live') or repro.api.planes.drive_orchestrator",
            DeprecationWarning, stacklevel=2)
        from repro.api.planes import drive_orchestrator

        return drive_orchestrator(self, scenario, requests, dt=dt,
                                  max_rounds=max_rounds)

    # -- introspection ---------------------------------------------------------------
    def stats(self) -> dict:
        rts = [r.response_time() for r in self.finished if r.response_time() is not None]
        out = {
            "finished": len(self.finished),
            "failed": len(self.failed),
            "queued": len(self.queue),
            "deferred": len(self.deferred),
            "active": sum(e.num_active for e in self.engines),
            "draining": sum(len(e.requests) for e in self.draining),
            "chains": [(list(e.chain.servers), e.capacity) for e in self.engines],
            "warming": sorted(self.warming),
            "c_star": self.c_star,
            "recompositions": self.recompositions,
            "mean_response": float(np.mean(rts)) if rts else math.nan,
        }
        if len(self.classes) > 1:
            out["per_class"] = self.per_class_stats()
        return out

    def per_class_stats(self) -> Dict[int, dict]:
        """Per-SLO-class completion counts and response quantiles."""
        out: Dict[int, dict] = {}
        for c, rc in enumerate(self.classes):
            rts = np.asarray([r.response_time() for r in self.finished
                              if r.cls == c and r.response_time() is not None])
            out[c] = {
                "name": rc.name,
                "finished": int(sum(1 for r in self.finished if r.cls == c)),
                "deferred": int(sum(1 for r in self.deferred if r.cls == c)),
                "mean_response": float(np.mean(rts)) if len(rts) else math.nan,
                "p99_response": float(np.percentile(rts, 99)) if len(rts)
                else math.nan,
                "slo_target": rc.slo_target,
            }
        return out
