"""Pipeline-parallel chain execution: the paper's placement, run as stages.

A chain is the GBP-CR placement (the paper's ``x`` variable) made concrete:
hop ``h`` of a chain puts ``chain.blocks[h]`` consecutive model blocks on
one server.  The monolithic engines (engine.py) preserve that structure
only in accounting — the whole block stack executes as one jit on one
device.  Here each hop becomes a *pipeline stage*: :func:`plan_stages`
maps the per-hop block counts to contiguous layer ranges, each range runs
on its own device of the 1-D ``"stage"`` mesh
(:func:`repro.distributed.stage_mesh`), holding only its layers' parameters
(:meth:`Model.layer_slice`) and — via :meth:`PagedCache.leaf_range` /
:meth:`SlotCache.leaf_range` — exactly its layers' KV leaves.  Slot and
page *accounting* stay shared by reference, and the per-stage memory
grants of :meth:`PageAccounting.split` sum to the paper's ``s_c``
bit-for-bit: sharding the cache never changes the control-plane contract.

Decode rounds run a microbatched 1F schedule: the active slots split into
``M`` microbatches; at tick ``t`` stage ``k`` runs microbatch ``t - k``,
so stage ``k+1`` processes microbatch ``j-1`` while stage ``k`` processes
``j`` — ``S + M - 1`` ticks per round instead of ``S * M`` stage-calls of
latency.  Activations hand off stage-to-stage via per-stage jit +
``device_put`` (the portable fallback of the shard_map collective-permute
design: XLA's CPU backend has no cross-device DMA, and explicit transfers
keep each stage's trace donate-able and device-committed).  Even with
stages sharing one physical core the schedule wins: batch size and page
count bucket *per microbatch* instead of globally, so e.g. a 9-slot round
pads to 4+2+2+2 = 10 decode rows at ``M=4`` where the monolithic engine
pads to 16 — less padded row work per layer at identical token streams.

Single-stage mode is the parity anchor: ``num_stages=1`` composes the
same embed → blocks → logits graph as ``PagedChainEngine._step_impl`` /
``ChainEngine`` and is CI-gated bit-identical to both monolithic engines
on both KV layouts; microbatching only regroups rows of a row-independent
batched decode, so any ``M`` yields the same greedy streams.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chains import Chain
from repro.distributed.mesh import stage_devices, stage_mesh
from repro.models import Model
from .engine import DECODE_SHAPE_LIMIT, PREFILL_BUCKET_LIMIT, _bucket, _pow2
from .kv_cache import PAGE_SIZE, PagedCache, SlotCache
from .request import Request, State


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One pipeline stage: a contiguous global layer range ``[lo, hi)`` and
    the chain hops (placement entries) whose blocks it executes."""

    index: int
    lo: int
    hi: int
    hops: Tuple[int, ...]

    @property
    def num_layers(self) -> int:
        return self.hi - self.lo


def plan_stages(blocks: Sequence[int], num_stages: int) -> List[StageSpec]:
    """Map the chain's per-hop block counts (one GBP-CR placement row) to
    ``num_stages`` contiguous layer ranges.

    Cuts prefer hop boundaries — a hop's blocks live on one server, and
    splitting inside a hop models slicing a server, which only happens when
    there are more stages than hops.  With fewer stages than hops,
    contiguous hops merge greedily toward equal layer counts; with more,
    ideal equal-layer cuts subdivide hops.  ``num_stages`` clamps to
    ``[1, total layers]``.
    """
    counts = [int(b) for b in blocks]
    if not counts or any(b <= 0 for b in counts):
        raise ValueError(f"hop block counts must be positive, got {blocks}")
    H = len(counts)
    L = sum(counts)
    S = max(1, min(int(num_stages), L))
    bounds = [0]
    for b in counts:
        bounds.append(bounds[-1] + b)
    specs: List[StageSpec] = []
    if S <= H:
        start = 0
        for k in range(S):
            stages_left = S - k
            max_end = H - (stages_left - 1)
            end = start + 1
            target = (L - bounds[start]) / stages_left
            while end < max_end:
                cur = bounds[end] - bounds[start]
                nxt = bounds[end + 1] - bounds[start]
                if abs(nxt - target) <= abs(cur - target):
                    end += 1
                else:
                    break
            specs.append(StageSpec(k, bounds[start], bounds[end],
                                   tuple(range(start, end))))
            start = end
    else:
        cuts = [0]
        for i in range(1, S):
            c = round(i * L / S)
            c = max(c, cuts[-1] + 1)
            cuts.append(min(c, L - (S - i)))
        cuts.append(L)
        for k in range(S):
            lo, hi = cuts[k], cuts[k + 1]
            hops = tuple(h for h in range(H)
                         if bounds[h] < hi and bounds[h + 1] > lo)
            specs.append(StageSpec(k, lo, hi, hops))
    return specs


class PipelineChainEngine:
    """Chain engine executing the hop placement as pipeline stages.

    Drop-in for ``ChainEngine`` / ``PagedChainEngine``: same factory
    signature ``(model, params, chain, capacity, max_seq)`` plus keyword
    knobs, same orchestrator surface (``admit`` / ``step`` / ``evict_all``
    / ``take_preempted`` / ``free_pages`` / ``prefill_bucket_count``), and
    — the contract the parity tests gate — identical greedy token streams.

    ``kv_layout`` picks the per-stage cache: ``"paged"`` shares one page
    accounting across stage-local pools (preemption on exhaustion, as in
    ``PagedChainEngine``); ``"slotted"`` shares the slot free list across
    stage-local slot buffers.  ``num_stages=None`` means one stage per
    chain hop.  ``microbatches`` bounds the decode-round split (clamped to
    the active-slot count each round).
    """

    def __init__(self, model: Model, params, chain: Chain, capacity: int,
                 max_seq: int, *, kv_layout: str = "paged",
                 page_size: int = PAGE_SIZE, oversubscribe: float = 1.0,
                 num_stages: Optional[int] = None, microbatches: int = 1,
                 devices: Optional[Sequence] = None,
                 trace_schedule: bool = False):
        if kv_layout not in ("slotted", "paged"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        if microbatches < 1:
            raise ValueError(f"microbatches must be >= 1, got {microbatches}")
        self.model = model
        self.chain = chain
        self.capacity = capacity
        self.max_seq = max_seq
        self.kv_layout = kv_layout
        self.page_size = page_size
        self.microbatches = int(microbatches)
        self.plan = plan_stages(
            chain.blocks, len(chain.blocks) if num_stages is None
            else int(num_stages))
        self.num_stages = len(self.plan)
        self.devices = stage_devices(self.num_stages, devices)
        self.mesh = stage_mesh(self.num_stages, devices)
        self.trace_schedule = trace_schedule
        self.stage_schedule: List[dict] = []

        self.slices = [model.layer_slice(sp.lo, sp.hi) for sp in self.plan]
        self.stage_params = [
            jax.device_put(sl.slice_params(params), dev)
            for sl, dev in zip(self.slices, self.devices)]

        if kv_layout == "paged":
            num_slots = max(1, int(capacity * oversubscribe))
            pages_per_slot = -(-max_seq // page_size)
            self.cache = PagedCache(model, num_slots, max_seq,
                                    page_size=page_size,
                                    total_pages=capacity * pages_per_slot,
                                    materialize=False)
        else:
            self.cache = SlotCache(model, capacity, max_seq,
                                   materialize=False)
        self.stage_caches = [self.cache.leaf_range(sl, device=dev)
                             for sl, dev in zip(self.slices, self.devices)]

        self.requests: Dict[int, Request] = {}      # slot -> request
        self.preempted: List[Request] = []
        self._admit_seq: Dict[int, int] = {}
        self._seq = 0
        self._round = 0

        S = self.num_stages
        self._prefill_jits = [jax.jit(self._make_prefill(k)) for k in range(S)]
        self._fixup_jits = [jax.jit(self._make_fixup(k)) for k in range(S)]
        if kv_layout == "paged":
            self._step_jits = [jax.jit(self._make_paged_step(k),
                                       donate_argnums=(1,)) for k in range(S)]
        else:
            self._step_jits = [jax.jit(self._make_slotted_step(k),
                                       donate_argnums=(1,)) for k in range(S)]
        self._prefill_shapes: set = set()
        self._step_shapes: List[set] = [set() for _ in range(S)]

    # -- stage programs ----------------------------------------------------------
    # Composed over all stages these are the *same graphs* the monolithic
    # engines jit (embed -> blocks -> logits; identical page gather/scatter),
    # split at hidden-state boundaries — the bit-parity anchor.

    def _make_prefill(self, k: int):
        sl = self.slices[k]
        first, last = k == 0, k == self.num_stages - 1
        model = self.model

        def fn(params, cache, x):
            if first:
                x = model.embed_inputs(params, {"tokens": x})
            x, new_cache = sl.seq_blocks(params, cache, x)
            out = model.logits(params, x[:, -1]) if last else x
            return out, new_cache
        return fn

    def _make_fixup(self, k: int):
        # bucketed-prefill boundary fixup: one decode step over the batch-1
        # stage buffers (the paged engine's buffer-side fixup, per stage)
        sl = self.slices[k]
        first, last = k == 0, k == self.num_stages - 1
        model = self.model

        def fn(params, cache, x, lengths):
            if first:
                x = jnp.take(params["embed"], x, axis=0)
            x, new_cache = sl.decode_blocks(params, cache, x, lengths)
            out = model.logits(params, x) if last else x
            return out, new_cache
        return fn

    def _make_paged_step(self, k: int):
        sl = self.slices[k]
        first, last = k == 0, k == self.num_stages - 1
        model = self.model
        view = self.stage_caches[k]

        def fn(params, leaves, page_ids, slot_idx, x, lengths,
               write_page, write_off):
            nb = lengths.shape[0]
            dense = []
            for leaf, paged in zip(leaves, view._paged):
                if paged:
                    g = leaf[:, page_ids]      # (L, nb, npg, page, *tail)
                    dense.append(g.reshape(leaf.shape[0], nb, -1,
                                           *leaf.shape[3:]))
                else:
                    dense.append(leaf[:, slot_idx])
            cache = jax.tree_util.tree_unflatten(view._treedef, dense)
            if first:
                x = jnp.take(params["embed"], x, axis=0)
            x, new_cache = sl.decode_blocks(params, cache, x, lengths)
            out = model.logits(params, x) if last else x
            new_flat, _ = jax.tree_util.tree_flatten(new_cache)
            rows = jnp.arange(nb)
            new_leaves = []
            for leaf, nd, paged in zip(leaves, new_flat, view._paged):
                if paged:
                    val = nd[:, rows, lengths]         # (L, nb, *tail)
                    new_leaves.append(
                        leaf.at[:, write_page, write_off].set(val))
                else:
                    new_leaves.append(leaf.at[:, slot_idx].set(nd))
            return out, new_leaves
        return fn

    def _make_slotted_step(self, k: int):
        sl = self.slices[k]
        first, last = k == 0, k == self.num_stages - 1
        model = self.model

        def fn(params, cache, rows, x, lengths):
            sub = jax.tree.map(lambda a: a[:, rows], cache)
            if first:
                x = jnp.take(params["embed"], x, axis=0)
            x, new_sub = sl.decode_blocks(params, sub, x, lengths)
            out = model.logits(params, x) if last else x
            new_cache = jax.tree.map(
                lambda full, nd: full.at[:, rows].set(nd), cache, new_sub)
            return out, new_cache
        return fn

    # -- jit-cache hygiene -------------------------------------------------------
    @property
    def prefill_bucket_count(self) -> int:
        return len(self._prefill_shapes)

    def _prefill_cache_guard(self, key) -> None:
        if key not in self._prefill_shapes \
                and len(self._prefill_shapes) >= PREFILL_BUCKET_LIMIT:
            for j in self._prefill_jits:
                j.clear_cache()
            for j in self._fixup_jits:
                j.clear_cache()
            self._prefill_shapes.clear()
        self._prefill_shapes.add(key)

    def _step_cache_guard(self, k: int, key) -> None:
        shapes = self._step_shapes[k]
        if key not in shapes and len(shapes) >= DECODE_SHAPE_LIMIT:
            self._step_jits[k].clear_cache()
            shapes.clear()
        shapes.add(key)

    # -- admission --------------------------------------------------------------
    @property
    def has_free_slot(self) -> bool:
        return bool(self.cache.free)

    @property
    def num_active(self) -> int:
        return len(self.requests)

    @property
    def free_pages(self) -> int:
        if self.kv_layout != "paged":
            # slotted engines have no page pool; AttributeError keeps the
            # orchestrator's hasattr() gauge filter honest
            raise AttributeError("free_pages")
        return self.cache.free_pages

    def admit(self, req: Request, now: float = 0.0) -> bool:
        tokens = req.context_tokens
        true_len = len(tokens)
        if self.kv_layout == "paged":
            slot = self.cache.acquire(true_len)
            if slot is None:
                return False             # no slot, or page budget exhausted
            pad_to = min(max(_bucket(true_len), self.page_size), self.max_seq)
        else:
            slot = self.cache.acquire()
            if slot is None:
                return False
            pad_to = min(_bucket(true_len), self.max_seq)
        padded = np.zeros((1, pad_to), np.int32)
        padded[0, :true_len] = tokens
        self._prefill_cache_guard((1, pad_to))
        # Prefill flows through the stages sequentially (batch-1: nothing to
        # overlap); each stage fills its own right-sized buffer.
        bufs = []
        x = jnp.asarray(padded)
        for k in range(self.num_stages):
            if self.kv_layout == "paged":
                buf = self.stage_caches[k].prefill_buffer(pad_to)
            else:
                buf = self.slices[k].init_cache(1, self.max_seq)
            x = jax.device_put(x, self.devices[k])
            x, buf = self._prefill_jits[k](self.stage_params[k], buf, x)
            bufs.append(buf)
        if true_len == pad_to:
            next_tok = int(jnp.argmax(x[0]))
        else:
            # boundary fixup as in the monolithic engines: re-feed the true
            # last token at its own position through all stages (identical
            # k/v rewritten, correct boundary logits)
            fx = jnp.asarray([int(tokens[-1])], jnp.int32)
            lpos = jnp.asarray([true_len - 1], jnp.int32)
            for k in range(self.num_stages):
                fx = jax.device_put(fx, self.devices[k])
                fx, bufs[k] = self._fixup_jits[k](
                    self.stage_params[k], bufs[k], fx,
                    jax.device_put(lpos, self.devices[k]))
            next_tok = int(jnp.argmax(fx[0]))
        for k in range(self.num_stages):
            self.stage_caches[k].write_prefill(slot, bufs[k], true_len)
        req.slot = slot
        req.state = State.RUNNING
        if req.start_time is None:
            req.start_time = now
        self.requests[slot] = req
        self._admit_seq[slot] = self._seq
        self._seq += 1
        req.output.append(next_tok)
        if req.done:
            req.state = State.DONE
            req.finish_time = now
            self._release(slot)
        return True

    def _release(self, slot: int) -> None:
        self.requests.pop(slot, None)
        self._admit_seq.pop(slot, None)
        self.cache.release(slot)

    def _preempt(self, slot: int) -> None:
        req = self.requests[slot]
        req.state = State.QUEUED
        req.slot = None
        req.chain_idx = None
        req.retries += 1
        self.preempted.append(req)
        self._release(slot)

    def take_preempted(self) -> List[Request]:
        out, self.preempted = self.preempted, []
        return out

    # -- decode ----------------------------------------------------------------
    def _run_stage(self, k: int, meta: dict, x):
        x = jax.device_put(x, self.devices[k])
        view = self.stage_caches[k]
        if self.kv_layout == "paged":
            self._step_cache_guard(
                k, (meta["page_ids"].shape, meta["slot_idx"].shape))
            out, view.leaves = self._step_jits[k](
                self.stage_params[k], view.leaves,
                jnp.asarray(meta["page_ids"]), jnp.asarray(meta["slot_idx"]),
                x, jnp.asarray(meta["lengths"]),
                jnp.asarray(meta["write_page"]), jnp.asarray(meta["write_off"]))
        else:
            self._step_cache_guard(k, meta["rows"].shape)
            out, view.cache = self._step_jits[k](
                self.stage_params[k], view.cache,
                jnp.asarray(meta["rows"]), x, jnp.asarray(meta["lengths"]))
        return out

    def step(self, now: float = 0.0) -> List[Request]:
        """One decode round: split the active slots into microbatches, run
        the 1F wavefront over the stages, then collect completions in
        ascending slot order (the monolithic engines' order)."""
        if not self.requests:
            return []
        if self.kv_layout == "paged":
            # guarantee a write page per active row, preempting the
            # youngest on exhaustion — identical to PagedChainEngine
            alive = sorted(self.requests, key=lambda s: self._admit_seq[s])
            for slot in list(alive):
                if slot not in alive:
                    continue
                while slot in alive \
                        and not self.cache.ensure_decode_write(slot):
                    self._preempt(alive.pop())
            if not alive:
                return []
        else:
            alive = list(self.requests)
        active = sorted(alive)
        M = min(self.microbatches, len(active))
        groups = [list(map(int, g)) for g in
                  np.array_split(np.asarray(active, np.int64), M)]
        S = self.num_stages
        # Per-microbatch gathered views, all against the round-start
        # accounting (each slot is in exactly one microbatch, so writes are
        # disjoint and group order cannot change any row's inputs).
        metas, xs = [], []
        for g in groups:
            gn = len(g)
            nb = _pow2(gn)
            tokens = np.zeros((nb,), np.int32)
            for i, slot in enumerate(g):
                tokens[i] = self.requests[slot].output[-1]
            tokens[gn:] = tokens[0]             # pad rows mirror row 0
            if self.kv_layout == "paged":
                npg = _pow2(max(int(self.cache.pages_used[s]) for s in g))
                metas.append(self.cache.decode_view(g, nb, npg))
            else:
                rows = np.asarray(g + [g[0]] * (nb - gn), np.int32)
                metas.append({"rows": rows,
                              "lengths": self.cache.lengths[rows]})
            xs.append(jnp.asarray(tokens))
        # 1F wavefront: tick t runs microbatch t-k on stage k (k descending
        # so a microbatch advances at most one stage per tick)
        for t in range(S + M - 1):
            for k in range(S - 1, -1, -1):
                j = t - k
                if 0 <= j < M:
                    xs[j] = self._run_stage(k, metas[j], xs[j])
                    if self.trace_schedule:
                        self.stage_schedule.append({
                            "now": now, "round": self._round, "tick": t,
                            "n_ticks": S + M - 1, "stage": k, "ubatch": j,
                            "rows": len(groups[j])})
        self._round += 1
        finished = []
        for j, g in enumerate(groups):
            nxt = np.asarray(jnp.argmax(xs[j][:len(g)], axis=-1))
            for i, slot in enumerate(g):
                self.cache.lengths[slot] += 1
                req = self.requests[slot]
                req.output.append(int(nxt[i]))
                if req.done:
                    req.state = State.DONE
                    req.finish_time = now
                    finished.append(req)
                    self._release(slot)
        return finished

    # -- failover ----------------------------------------------------------------
    def evict_all(self) -> List[Request]:
        out = []
        for slot, req in list(self.requests.items()):
            req.state = State.QUEUED
            req.slot = None
            req.chain_idx = None
            req.retries += 1
            out.append(req)
            self.cache.release(slot)
        self.requests.clear()
        self._admit_seq.clear()
        out.extend(self.take_preempted())
        return out
