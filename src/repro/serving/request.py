"""Request lifecycle for the serving orchestrator."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import numpy as np


class State(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"
    DEFERRED = "deferred"    # parked by the admission gate; readmitted later


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32 token ids
    max_new_tokens: int
    arrival_time: float = 0.0
    eos_token: Optional[int] = None
    cls: int = 0                        # index into the orchestrator's
    #                                     RequestClass list (SLO class)
    # runtime state
    state: State = State.QUEUED
    output: List[int] = dataclasses.field(default_factory=list)
    chain_idx: Optional[int] = None
    slot: Optional[int] = None
    start_time: Optional[float] = None
    finish_time: Optional[float] = None
    retries: int = 0

    @property
    def context_tokens(self) -> np.ndarray:
        """Prompt plus generated-so-far (used to re-prefill after failover)."""
        if not self.output:
            return self.prompt
        return np.concatenate([self.prompt, np.asarray(self.output, np.int32)])

    @property
    def done(self) -> bool:
        if len(self.output) >= self.max_new_tokens:
            return True
        return bool(self.output) and self.eos_token is not None \
            and self.output[-1] == self.eos_token

    def response_time(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time

    def waiting_time(self) -> Optional[float]:
        if self.start_time is None:
            return None
        return self.start_time - self.arrival_time
