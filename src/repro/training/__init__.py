from .optimizer import AdamWConfig, adamw_init, adamw_update, global_norm, schedule
from .train_loop import TrainConfig, init_train_state, make_train_step
from . import checkpoint, data

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "global_norm", "schedule",
    "TrainConfig", "init_train_state", "make_train_step",
    "checkpoint", "data",
]
