"""Adafactor (factored second moment, momentum-free) — the memory-lean
optimizer used for the largest train cells (deepseek-v3-671b on 256 v5e chips
cannot hold AdamW moments; Adafactor's factored v is ~(rows+cols) instead of
rows*cols).  Follows Shazeer & Stern (arXiv:1804.04235) with update clipping.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-2
    decay_rate: float = 0.8      # beta2_t = 1 - t^{-decay}
    eps1: float = 1e-30
    eps2: float = 1e-3
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    warmup_steps: int = 100


def adafactor_init(cfg: AdafactorConfig, params: Any) -> Dict[str, Any]:
    def factored(p):
        if p.ndim >= 2:
            return {
                "v_row": jnp.zeros(p.shape[:-1], jnp.float32),
                "v_col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "v": jax.tree.map(factored, params,
                          is_leaf=lambda x: isinstance(x, jnp.ndarray)),
        "step": jnp.zeros((), jnp.int32),
    }


def _rms(x):
    return jnp.sqrt(jnp.mean(jnp.square(x)) + 1e-30)


def adafactor_update(
    cfg: AdafactorConfig, params: Any, grads: Any, state: Dict[str, Any],
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay_rate)
    lr = cfg.lr * jnp.minimum(1.0, t / cfg.warmup_steps)

    def upd(p, g, v):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + cfg.eps1
        if p.ndim >= 2:
            v_row = beta2 * v["v_row"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            v_col = beta2 * v["v_col"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            row_mean = jnp.mean(v_row, axis=-1, keepdims=True)
            vhat = (v_row / jnp.maximum(row_mean, cfg.eps1))[..., None] * v_col[..., None, :]
            new_v = {"v_row": v_row, "v_col": v_col}
        else:
            vhat = beta2 * v["v"] + (1 - beta2) * g2
            new_v = {"v": vhat}
        u = g32 / jnp.sqrt(jnp.maximum(vhat, cfg.eps1))
        u = u / jnp.maximum(1.0, _rms(u) / cfg.clip_threshold)
        scale = jnp.maximum(_rms(p.astype(jnp.float32)), cfg.eps2)
        delta = lr * scale * u
        if cfg.weight_decay and p.ndim >= 2:
            delta = delta + lr * cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - delta).astype(p.dtype), new_v

    # state["v"] holds a small dict per param leaf; pair leaves explicitly.
    is_state_leaf = lambda x: isinstance(x, dict) and ("v" in x or "v_row" in x)
    treedef = jax.tree_util.tree_structure(params)
    p_leaves = jax.tree_util.tree_leaves(params)
    g_leaves = jax.tree_util.tree_leaves(grads)
    v_leaves = jax.tree_util.tree_leaves(state["v"], is_leaf=is_state_leaf)
    new_params_leaves, new_v_leaves = [], []
    for p, g, v in zip(p_leaves, g_leaves, v_leaves):
        np_, nv = upd(p, g, v)
        new_params_leaves.append(np_)
        new_v_leaves.append(nv)
    new_params = jax.tree_util.tree_unflatten(treedef, new_params_leaves)
    new_v = jax.tree_util.tree_unflatten(treedef, new_v_leaves)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in g_leaves))
    return new_params, {"v": new_v, "step": step}, {"grad_norm": gnorm, "lr": lr}
