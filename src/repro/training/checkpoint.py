"""Fault-tolerant checkpointing: tensor-chunked, zstd-compressed, atomic.

Layout:  <dir>/step_<N>/
            manifest.json       (tree structure, dtypes, shapes, metadata)
            data.bin.zst        (concatenated raw tensor bytes)
         <dir>/LATEST           (atomic pointer file)

Writes go to a temp dir + atomic rename, so a crash mid-save never corrupts
the latest checkpoint — the restart path (``restore_latest``) always sees a
complete step.  ``save_async`` snapshots to host memory synchronously and
writes on a background thread (training continues).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

try:
    import zstandard
except ImportError:          # optional: fall back to uncompressed checkpoints
    zstandard = None

_SEP = "/"


def _codec() -> str:
    return "zstd" if zstandard is not None else "raw"


class _RawWriter:
    """stream_writer-compatible passthrough when zstandard is unavailable."""

    def __init__(self, f):
        self._f = f

    def __enter__(self):
        return self._f

    def __exit__(self, *exc):
        return False


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree: Any, metadata: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    treedef = jax.tree_util.tree_structure(tree)
    entries = []
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        with open(os.path.join(tmp, "data.bin.zst"), "wb") as f:
            writer = (zstandard.ZstdCompressor(level=3).stream_writer(f)
                      if zstandard is not None else _RawWriter(f))
            with writer as w:
                off = 0
                for name in sorted(flat):
                    arr = flat[name]
                    raw = np.ascontiguousarray(arr).tobytes()
                    entries.append({
                        "name": name, "dtype": str(arr.dtype),
                        "shape": list(arr.shape), "offset": off, "nbytes": len(raw),
                    })
                    w.write(raw)
                    off += len(raw)
        manifest = {
            "step": step,
            "entries": entries,
            "treedef": str(treedef),
            "codec": _codec(),
            "metadata": metadata or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # atomic LATEST pointer
    ptr_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(f"step_{step:08d}")
    os.replace(ptr_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def save_async(ckpt_dir: str, step: int, tree: Any,
               metadata: Optional[dict] = None) -> threading.Thread:
    """Snapshot to host now; write in the background."""
    host_tree = jax.device_get(tree)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree, metadata))
    t.start()
    return t


def restore(path: str, like: Any) -> Tuple[Any, dict]:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    codec = manifest.get("codec", "zstd")   # pre-codec checkpoints were zstd
    with open(os.path.join(path, "data.bin.zst"), "rb") as f:
        if codec == "zstd":
            if zstandard is None:
                raise ImportError(
                    "checkpoint was written with zstd compression but "
                    "zstandard is not installed")
            raw = zstandard.ZstdDecompressor().stream_reader(f).read()
        else:
            raw = f.read()
    flat = {}
    for e in manifest["entries"]:
        buf = raw[e["offset"]: e["offset"] + e["nbytes"]]
        flat[e["name"]] = np.frombuffer(buf, dtype=e["dtype"]).reshape(e["shape"])
    like_flat = _flatten(like)
    if set(like_flat) != set(flat):
        missing = set(like_flat) ^ set(flat)
        raise ValueError(f"checkpoint/tree structure mismatch: {sorted(missing)[:5]}")
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    out_leaves = []
    for path_k, leaf in leaves_with_path:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_k)
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {np.shape(leaf)}")
        out_leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, out_leaves), manifest


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore_latest(ckpt_dir: str, like: Any) -> Optional[Tuple[Any, dict]]:
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    return restore(os.path.join(ckpt_dir, f"step_{step:08d}"), like)
