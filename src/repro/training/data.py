"""Synthetic data pipeline: deterministic, seeded, shard-aware.

Produces next-token-prediction batches (tokens, labels) — labels are tokens
shifted by one inside a contiguous stream, mimicking a packed-document
pipeline.  For frontend-stub archs (vlm / audio) it synthesizes the embedding
inputs too.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ModelConfig


class SyntheticTokenStream:
    """Zipfian token stream with document boundaries (more realistic than
    uniform random for loss curves)."""

    def __init__(self, vocab_size: int, seed: int = 0, zipf_a: float = 1.2,
                 mean_doc_len: int = 512, bos: int = 0):
        self.vocab = vocab_size
        self.rng = np.random.default_rng(seed)
        self.zipf_a = zipf_a
        self.mean_doc = mean_doc_len
        self.bos = bos
        self._buf = np.empty((0,), np.int32)

    def _fill(self, n: int) -> None:
        chunks = [self._buf]
        total = len(self._buf)
        while total < n:
            dl = max(int(self.rng.exponential(self.mean_doc)), 8)
            doc = self.rng.zipf(self.zipf_a, size=dl).astype(np.int64)
            doc = (doc % (self.vocab - 1)) + 1          # keep 0 as BOS
            doc[0] = self.bos
            chunks.append(doc.astype(np.int32))
            total += dl
        self._buf = np.concatenate(chunks)

    def take(self, n: int) -> np.ndarray:
        self._fill(n + 1)
        out = self._buf[: n + 1].copy()
        self._buf = self._buf[n:]
        return out


def batches(cfg: ModelConfig, batch_size: int, seq_len: int,
            seed: int = 0, shard: int = 0, num_shards: int = 1,
            ) -> Iterator[Dict[str, np.ndarray]]:
    """Yields {tokens, labels(, patch_embeds | embeds)} batches forever.

    ``shard``/``num_shards`` give disjoint streams for data parallelism and
    deterministic restart (the stream is a pure function of (seed, shard))."""
    stream = SyntheticTokenStream(cfg.vocab_size, seed=seed * 1000 + shard)
    rng = np.random.default_rng(seed * 7777 + shard)
    P = cfg.num_prefix_embeds if cfg.family == "vlm" else 0
    text_len = seq_len - P
    while True:
        toks = np.stack([stream.take(text_len) for _ in range(batch_size)])
        batch: Dict[str, np.ndarray] = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        if cfg.family == "vlm":
            batch["patch_embeds"] = rng.standard_normal(
                (batch_size, P, cfg.d_model), dtype=np.float32)
        elif cfg.family == "audio":
            batch["embeds"] = rng.standard_normal(
                (batch_size, text_len - 1, cfg.d_model), dtype=np.float32)
            del batch["tokens"]
        yield batch
