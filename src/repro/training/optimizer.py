"""AdamW with global-norm clipping (no external deps).

Optimizer-state dtype is configurable: bf16 moments halve HBM at 1000+-chip
scale (the dry-run memory budget for deepseek-v3 on v5e requires it; see
DESIGN.md §5) at a well-understood small quality cost.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "bfloat16"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_init(cfg: AdamWConfig, params: Any) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def adamw_update(
    cfg: AdamWConfig, params: Any, grads: Any, state: Dict[str, Any],
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step.astype(jnp.float32))
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p32 = p.astype(jnp.float32) - lr * delta
        return p32.astype(p.dtype), m32.astype(sdt), v32.astype(sdt)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
