"""Training step factory: loss -> grad -> clip -> AdamW, with optional
gradient accumulation over microbatches (scan, so HLO stays compact)."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import Model
from .adafactor import AdafactorConfig, adafactor_init, adafactor_update
from .optimizer import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    adafactor: AdafactorConfig = AdafactorConfig()
    optimizer_name: str = "adamw"   # adamw | adafactor (memory-lean; huge models)
    grad_accum: int = 1             # microbatches per step
    accum_dtype: str = "float32"    # grad accumulator ("bfloat16" at 671B scale)


def make_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``batch`` leaves have leading dim global_batch; with grad_accum > 1 they
    are split into (A, B/A, ...) microbatches accumulated via lax.scan.
    """

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(params, opt_state, batch):
        A = tcfg.grad_accum
        if A == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(A, x.shape[0] // A, *x.shape[1:]), batch)

            acc_dt = jnp.dtype(tcfg.accum_dtype)

            def acc_step(carry, mb):
                loss_a, g_a = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (loss_a + l / A,
                        jax.tree.map(lambda a, b: (a + (b / A).astype(acc_dt)),
                                     g_a, g)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            (loss, grads), _ = jax.lax.scan(acc_step, (jnp.zeros((), jnp.float32), zeros), micro)
        if tcfg.optimizer_name == "adafactor":
            params, opt_state, metrics = adafactor_update(
                tcfg.adafactor, params, grads, opt_state)
        else:
            params, opt_state, metrics = adamw_update(
                tcfg.optimizer, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def init_opt_state(tcfg: TrainConfig, params) -> Any:
    if tcfg.optimizer_name == "adafactor":
        return adafactor_init(tcfg.adafactor, params)
    return adamw_init(tcfg.optimizer, params)


def init_train_state(model: Model, tcfg: TrainConfig, key) -> Tuple[Any, Any]:
    params = model.init(key)
    return params, init_opt_state(tcfg, params)
