"""Shared fixtures + optional-dependency shims for the test suite.

The property tests use ``hypothesis`` when it is installed.  When it is not
(the default container image has only numpy/jax/pytest), this conftest
installs a minimal stub into ``sys.modules`` whose ``@given`` decorator turns
each property test into a clean ``pytest.skip`` with an explanatory reason —
so the suite always *collects* and the deterministic tests still run.
"""
from __future__ import annotations

import random
import sys
import types

import pytest

# ---------------------------------------------------------------------------
# hypothesis shim
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert placeholder for hypothesis strategies."""

        def __init__(self, name, *args, **kwargs):
            self.name = name
            self.args = args
            self.kwargs = kwargs

        def __repr__(self):
            return f"<stub strategy {self.name}>"

        # strategies compose via methods like .map/.filter/.flatmap
        def __getattr__(self, item):
            return lambda *a, **k: self

    def _make_strategies_module():
        st_mod = types.ModuleType("hypothesis.strategies")

        def _factory(name):
            return lambda *a, **k: _Strategy(name, *a, **k)

        for name in (
            "integers", "floats", "booleans", "text", "lists", "tuples",
            "sampled_from", "one_of", "just", "none", "dictionaries",
            "composite", "builds", "binary", "characters", "sets",
        ):
            setattr(st_mod, name, _factory(name))
        return st_mod

    def _given(*_args, **_kwargs):
        def decorate(fn):
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed; property test skipped "
                            "(pip install hypothesis to run it)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return decorate

    def _settings(*_args, **_kwargs):
        def decorate(fn):
            return fn
        return decorate

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.assume = lambda *a, **k: None
    _hyp.note = lambda *a, **k: None
    _hyp.example = lambda *a, **k: (lambda fn: fn)
    _hyp.HealthCheck = types.SimpleNamespace(all=lambda: [])
    _hyp.strategies = _make_strategies_module()
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _hyp.strategies


# ---------------------------------------------------------------------------
# Shared small-cluster fixtures (used by the simulator/scenario tests)
# ---------------------------------------------------------------------------

@pytest.fixture
def small_spec():
    """A small chain-structured service: 10 blocks, BLOOM-like sizes."""
    from repro.core import ServiceSpec

    return ServiceSpec(num_blocks=10, block_size_gb=1.32, cache_size_gb=0.11)


@pytest.fixture
def small_cluster():
    """8 heterogeneous servers able to host the ``small_spec`` service."""
    from repro.core import Server

    rng = random.Random(1234)
    return [
        Server(f"s{i}", rng.uniform(15, 40), rng.uniform(0.02, 0.2),
               rng.uniform(0.02, 0.2))
        for i in range(8)
    ]


@pytest.fixture
def job_servers():
    """Composed job servers as (mu, c) pairs, descending rate."""
    return [(1.0, 2), (0.8, 2), (0.5, 4)]


def run_scenario_spec(servers, service, sc, base_rate=None, policy="jffc",
                      seed=0, arrivals=None, controller=None,
                      service_model="work", classes=None, class_rates=None,
                      aging_rate=0.0, admission_level=1.0):
    """The scenario engine via the experiment API on the old keyword
    surface the pre-API regressions were written against — shared by
    test_scenarios / test_autoscale / test_multitenant so none of them
    touches the deprecated ``run_scenario`` shim (whose warning is an
    error under this suite, see pytest.ini)."""
    from repro import api

    spec = api.ExperimentSpec(
        cluster=api.ClusterSpec(servers=tuple(servers), service=service),
        scenario=api.ScenarioSpec.from_scenario(sc),
        workload=api.WorkloadSpec(
            base_rate=base_rate,
            class_rates=None if class_rates is None else tuple(class_rates),
            classes=tuple(classes) if classes else (),
            service_model=service_model),
        policy=api.PolicySpec(name=policy, aging_rate=aging_rate),
        admission=api.AdmissionSpec(level=admission_level),
        seed=seed)
    return api.run(spec, arrivals=arrivals, controller=controller).raw
