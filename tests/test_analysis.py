"""HLO cost parser + roofline unit tests (the dry-run's measurement layer)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_parse import parse_costs, _shape_bytes
from repro.analysis.roofline import RooflineTerms, build_terms, model_flops_for
from repro.configs import SHAPES, get


def test_shape_bytes_parsing():
    assert _shape_bytes("bf16[4,8]") == 64
    assert _shape_bytes("f32[10]") == 40
    assert _shape_bytes("(f32[2,2], bf16[4])") == 24   # tuples sum
    assert _shape_bytes("pred[]") == 1
    assert _shape_bytes("%foo") == 0


def test_parse_costs_scan_trip_counts():
    """dot FLOPs inside a scanned body must be multiplied by the trip count."""
    L, B, D = 5, 4, 16

    def model(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, ws)
        return out.sum()

    ws = jnp.ones((L, D, D))
    x = jnp.ones((B, D))
    txt = jax.jit(model).lower(ws, x).compile().as_text()
    costs = parse_costs(txt)
    analytic = 2 * B * D * D * L
    assert costs.flops == pytest.approx(analytic, rel=0.05), (
        f"parsed {costs.flops} vs analytic {analytic}")


def test_parse_costs_grad_counts_backward():
    B, D = 8, 32

    def loss(w, x):
        return jnp.sum((x @ w) ** 2)

    w = jnp.ones((D, D))
    x = jnp.ones((B, D))
    txt = jax.jit(jax.grad(loss)).lower(w, x).compile().as_text()
    costs = parse_costs(txt)
    fwd = 2 * B * D * D
    # grad wrt w only: fwd + dw = 2 matmuls (dx is never materialized)
    assert 1.5 * fwd <= costs.flops <= 2.5 * fwd
    assert costs.bytes > 0


def test_roofline_terms_and_dominance():
    t = build_terms(flops_total=197e12 * 256, bytes_total=819e9,
                    collective_bytes=1.0, chips=256, model_flops=197e12 * 256)
    assert t.compute_s == pytest.approx(1.0)
    assert t.dominant == "compute"
    assert t.roofline_fraction == pytest.approx(1.0)
    t2 = build_terms(flops_total=1.0, bytes_total=819e9 * 256 * 2,
                     collective_bytes=1.0, chips=256, model_flops=1.0)
    assert t2.dominant == "memory" and t2.memory_s == pytest.approx(2.0)


def test_model_flops_scaling():
    cfg = get("qwen3-8b")
    tr = model_flops_for(cfg, SHAPES["train_4k"])
    pf = model_flops_for(cfg, SHAPES["prefill_32k"])
    dc = model_flops_for(cfg, SHAPES["decode_32k"])
    # train is 3x inference per token; decode is per-token tiny
    tokens_tr = 256 * 4096
    tokens_pf = 32 * 32768
    # per-token: train = 3x inference on weights, but prefill_32k carries 8x
    # the attention context -> net ratio lands between 1.5 and 3
    assert 1.5 < (tr / tokens_tr) / (pf / tokens_pf) < 3.0
    assert dc < pf / 100
    # MoE active params < total
    ds = get("deepseek-v3-671b")
    assert ds.active_param_count() < 0.1 * ds.total_param_count()
    assert model_flops_for(ds, SHAPES["train_4k"]) < 6 * ds.total_param_count() * tokens_tr


def test_constrain_noop_without_context():
    from repro.distributed.annotate import constrain

    x = jnp.ones((4, 4))
    assert constrain(x, "batch", None) is x


def test_constrain_divisibility_and_duplicates():
    from repro.distributed.annotate import constrain, logical_sharding, rules_for

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with logical_sharding(mesh, rules_for(mesh, seq="model")):
        # same mesh axis requested twice -> second occurrence dropped, no error
        out = jax.jit(lambda x: constrain(x, "seq", "vocab"))(jnp.ones((4, 4)))
        np.testing.assert_array_equal(np.asarray(out), np.ones((4, 4)))


def test_sharding_rules_divisibility_guard():
    from repro.configs import get
    from repro.distributed.sharding import ShardingContext, param_pspec

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = ShardingContext(mesh, get("hymba-1.5b"), "serve")
    # hymba vocab 32001 doesn't divide any axis size > 1; with axis size 1
    # everything "fits" — just exercise the path on realistic leaves:
    class Leaf:
        def __init__(self, shape):
            self.shape = shape
    spec = param_pspec(ctx, (), Leaf((32001, 1600)))
    assert spec is not None
