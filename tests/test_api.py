"""The declarative experiment API (repro.api).

Four pillars, matching the refactor's acceptance gates:

* **round-trip** — ``from_dict(to_dict(spec)) == spec`` across randomized
  specs (and through JSON, including inf-valued SLO fields), with
  validation errors that name the offending field;
* **shim parity** — the deprecated ``run_scenario`` entry points are
  bit-identical to direct ``repro.api.run`` on fixed seeds, for scripted
  scenarios, all eight dispatch policies, multi-tenant priority runs and
  controller-driven runs;
* **plane agnosticism** — the same spec executes on ``SimPlane`` and
  ``LivePlane(mock)`` and returns one ``RunReport`` schema (diffable);
* **registries** — policies/tuners/workloads/event kinds/scalers extend by
  decorator with zero core edits.

Numpy-only: no jax anywhere (the CI ``api-smoke`` job runs this file in a
minimal environment).
"""
import dataclasses
import json
import math
import random
import warnings

import numpy as np
import pytest

from repro import api
from repro.core import (
    RequestClass,
    Scenario,
    ScenarioEvent,
    Server,
    ServiceSpec,
    VECTORIZED_POLICIES,
    run_scenario,
    simulate_vectorized,
)
from repro.core import scenarios as core_scenarios
from repro.core.workload import poisson_exponential_np

SERVICE = ServiceSpec(num_blocks=10, block_size_gb=1.32, cache_size_gb=0.11)
JOB_SERVERS = ((1.0, 4), (0.8, 4), (0.5, 8))
NU = sum(m * c for m, c in JOB_SERVERS)
TEMPLATE = Server("tmpl", 30.0, 0.05, 0.05)


def cluster(n=8, seed=1234):
    rng = random.Random(seed)
    return tuple(Server(f"s{i}", rng.uniform(15, 40), rng.uniform(0.02, 0.2),
                        rng.uniform(0.02, 0.2)) for i in range(n))


def scripted_scenario(servers, horizon=120.0) -> Scenario:
    return (Scenario(horizon=horizon, description="fail+burst+recover")
            .fail(horizon * 0.3, "s3")
            .burst(horizon * 0.5, horizon * 0.15, 4.0)
            .recover(horizon * 0.7, servers[3]))


def base_spec(servers=None, horizon=120.0, **kw) -> api.ExperimentSpec:
    servers = cluster() if servers is None else servers
    defaults = dict(
        cluster=api.ClusterSpec(servers=servers, service=SERVICE),
        scenario=api.ScenarioSpec.from_scenario(
            scripted_scenario(servers, horizon)),
        workload=api.WorkloadSpec(base_rate=3.0),
        seed=0,
    )
    defaults.update(kw)
    return api.ExperimentSpec(**defaults)


def no_deprecation(fn, *args, **kwargs):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return fn(*args, **kwargs)


# ---------------------------------------------------------------------------
# Round-trip
# ---------------------------------------------------------------------------

def _random_spec(rng: random.Random) -> api.ExperimentSpec:
    """A randomized-but-valid spec touching most of the surface."""
    horizon = rng.uniform(50.0, 500.0)
    servers = cluster(rng.randint(4, 10), seed=rng.randrange(10_000))
    precomposed = rng.random() < 0.3
    if precomposed:
        cl = api.ClusterSpec(job_servers=tuple(
            (round(rng.uniform(0.2, 2.0), 3), rng.randint(1, 8))
            for _ in range(rng.randint(1, 4))),
            engine=rng.choice(list(api.ENGINES)))
        sc = api.ScenarioSpec(
            horizon=horizon,
            events=(ScenarioEvent(horizon * 0.4, "burst", scale=3.0,
                                  duration=horizon * 0.1),))
    else:
        cl = api.ClusterSpec(
            servers=servers, service=SERVICE,
            rho_bar=round(rng.uniform(0.4, 0.95), 2),
            tuner=rng.choice(list(api.TUNERS)),
            engine=rng.choice(list(api.ENGINES)))
        sc = api.ScenarioSpec.from_scenario(scripted_scenario(
            servers, horizon))
    classed = rng.random() < 0.5
    classes = ()
    class_rates = None
    if classed:
        classes = (RequestClass("interactive", "chat", 0, slo_target=2.0),
                   RequestClass("batch", "offline", 1,
                                deadline=rng.choice([math.inf, 30.0])))
        class_rates = (round(rng.uniform(0.5, 3.0), 3),
                       round(rng.uniform(0.5, 3.0), 3))
    autoscale = None
    if not precomposed and rng.random() < 0.5:
        scaler = rng.choice(list(api.SCALERS))
        params = {}
        if scaler == "slo-admission":
            params = {"slo": 4.0, "inner": {"policy": "target-util",
                                            "params": {"high": 0.9}}}
        elif scaler == "predictive":
            params = {"lead": round(rng.uniform(10.0, 40.0), 1)}
        autoscale = api.AutoscaleSpec(
            policy=scaler, template=TEMPLATE, params=params,
            interval=round(rng.uniform(2.0, 10.0), 1),
            max_servers=rng.randint(4, 32),
            slo_response_time=rng.choice([None, 4.0]))
    return api.ExperimentSpec(
        cluster=cl,
        scenario=sc,
        workload=api.WorkloadSpec(
            base_rate=round(rng.uniform(1.0, 8.0), 3),
            class_rates=class_rates,
            classes=classes,
            seed=rng.choice([None, rng.randrange(100)])),
        policy=api.PolicySpec(
            name=rng.choice(list(VECTORIZED_POLICIES)),
            aging_rate=rng.choice([0.0, 0.001])),
        admission=api.AdmissionSpec(level=rng.choice([1.0, 0.5])),
        autoscale=autoscale,
        seed=rng.randrange(1000),
        warmup_fraction=rng.choice([0.0, 0.1]),
        name=f"rand-{rng.randrange(10_000)}")


def test_roundtrip_property_randomized_specs():
    """from_dict(to_dict(spec)) == spec — 40 randomized specs, dict and
    JSON paths both."""
    rng = random.Random(7)
    for _ in range(40):
        spec = _random_spec(rng)
        d = spec.to_dict()
        back = api.ExperimentSpec.from_dict(d)
        assert back == spec
        back_json = api.ExperimentSpec.from_json(spec.to_json())
        assert back_json == spec
        # to_dict output is strictly JSON-serializable (inf encodes)
        json.dumps(d)


def test_roundtrip_preserves_infinite_slo_fields():
    spec = base_spec(workload=api.WorkloadSpec(
        base_rate=2.0,
        classes=(RequestClass("a", "t", 0),
                 RequestClass("b", "t", 1, deadline=10.0)),
        class_rates=(1.0, 1.0)))
    s = spec.to_json()
    assert '"inf"' in s
    back = api.ExperimentSpec.from_json(s)
    assert back.workload.classes[0].deadline == math.inf
    assert back == spec


def test_run_after_roundtrip_is_bit_identical():
    """Acceptance: spec -> to_dict -> from_dict -> run reproduces the
    direct-spec result exactly."""
    spec = base_spec()
    direct = api.run(spec)
    rebuilt = api.run(api.ExperimentSpec.from_dict(spec.to_dict()))
    assert np.array_equal(direct.raw.result.response_times,
                          rebuilt.raw.result.response_times)
    assert direct.to_dict() == rebuilt.to_dict()


# ---------------------------------------------------------------------------
# Validation errors name the bad field
# ---------------------------------------------------------------------------

def test_unknown_policy_names_field():
    with pytest.raises(api.SpecError, match="policy.name.*nosuch"):
        api.PolicySpec(name="nosuch")


def test_unknown_tuner_names_field():
    with pytest.raises(api.SpecError, match="cluster.tuner.*warp"):
        api.ClusterSpec(servers=cluster(), service=SERVICE, tuner="warp")


def test_unknown_generator_names_field():
    with pytest.raises(api.SpecError, match="workload.generator"):
        api.WorkloadSpec(generator="nope", base_rate=1.0)


def test_unknown_scaler_names_field():
    with pytest.raises(api.SpecError, match="autoscale.policy"):
        api.AutoscaleSpec(policy="nope", template=TEMPLATE)


def test_unknown_event_kind_names_indexed_field():
    d = base_spec().to_dict()
    d["scenario"]["events"][0]["kind"] = "explode"
    with pytest.raises(api.SpecError, match=r"scenario.events\[0\].kind"):
        api.ExperimentSpec.from_dict(d)


def test_unknown_dict_key_names_field():
    d = base_spec().to_dict()
    d["workload"]["bogus"] = 1
    with pytest.raises(api.SpecError, match="workload.bogus"):
        api.ExperimentSpec.from_dict(d)


def test_cluster_needs_exactly_one_of_servers_or_job_servers():
    with pytest.raises(api.SpecError, match="cluster"):
        api.ClusterSpec()
    with pytest.raises(api.SpecError, match="cluster"):
        api.ClusterSpec(servers=cluster(), service=SERVICE,
                        job_servers=JOB_SERVERS)


def test_precomposed_cluster_rejects_cluster_events_and_autoscale():
    servers = cluster()
    with pytest.raises(api.SpecError, match="scenario.events"):
        api.ExperimentSpec(
            cluster=api.ClusterSpec(job_servers=JOB_SERVERS),
            scenario=api.ScenarioSpec.from_scenario(
                scripted_scenario(servers)),
            workload=api.WorkloadSpec(base_rate=1.0))
    with pytest.raises(api.SpecError, match="autoscale"):
        api.ExperimentSpec(
            cluster=api.ClusterSpec(job_servers=JOB_SERVERS),
            scenario=api.ScenarioSpec(horizon=100.0),
            workload=api.WorkloadSpec(base_rate=1.0),
            autoscale=api.AutoscaleSpec(policy="predictive",
                                        template=TEMPLATE))


def test_missing_rate_names_field():
    with pytest.raises(api.SpecError, match="workload.base_rate"):
        base_spec(workload=api.WorkloadSpec())


def test_class_rates_length_mismatch_names_field():
    with pytest.raises(api.SpecError, match="workload.class_rates"):
        api.WorkloadSpec(class_rates=(1.0,),
                         classes=(RequestClass(), RequestClass("b")))


# ---------------------------------------------------------------------------
# Seed derivation rule
# ---------------------------------------------------------------------------

def test_seed_rule_is_centralized():
    spec = base_spec(seed=41)
    assert api.ENGINE_SEED_OFFSET == 1
    assert spec.engine_seed() == 42
    assert spec.workload_seed() == 41
    override = spec.replace(workload=dataclasses.replace(
        spec.workload, seed=7))
    assert override.workload_seed() == 7
    assert override.engine_seed() == 42   # engine stream is never overridden


# ---------------------------------------------------------------------------
# Shim parity: deprecated entry points == repro.api.run, bit for bit
# ---------------------------------------------------------------------------

def test_run_scenario_shim_warns_and_matches_api_run():
    servers = cluster()
    sc = scripted_scenario(servers)
    with pytest.warns(DeprecationWarning):
        old = run_scenario(servers, SERVICE, sc, base_rate=3.0, seed=0)
    rep = api.run(base_spec(servers))
    assert np.array_equal(old.result.response_times,
                          rep.raw.result.response_times)
    assert np.array_equal(old.result.waiting_times,
                          rep.raw.result.waiting_times)
    assert old.result.sim_time == rep.raw.result.sim_time
    assert [dataclasses.asdict(e) for e in old.log] == rep.events


@pytest.mark.parametrize("policy", VECTORIZED_POLICIES)
def test_all_eight_policies_bit_identical_via_spec(policy):
    n, lam, seed = 4000, 0.85 * NU, 5
    arrivals = poisson_exponential_np(lam, n, seed=seed)
    old = simulate_vectorized(policy, list(JOB_SERVERS), arrivals, seed=seed)
    spec = api.ExperimentSpec(
        cluster=api.ClusterSpec(job_servers=JOB_SERVERS),
        scenario=api.ScenarioSpec(horizon=float(arrivals[0][-1]) + 1.0),
        workload=api.WorkloadSpec(generator="poisson", base_rate=lam,
                                  params={"n": n}),
        policy=api.PolicySpec(name=policy),
        seed=seed, warmup_fraction=0.1)
    rep = api.run(spec)
    assert np.array_equal(old.response_times, rep.raw.result.response_times)
    assert np.array_equal(old.waiting_times, rep.raw.result.waiting_times)
    assert old.sim_time == rep.raw.result.sim_time


def test_multitenant_priority_run_bit_identical_via_spec():
    servers = cluster()
    classes = (RequestClass("interactive", "chat", 0, slo_target=2.0),
               RequestClass("batch", "offline", 1, deadline=10.0))
    sc = Scenario(horizon=150.0).tenant_burst(50.0, 40.0, 3.0, cls=0)
    old = no_deprecation(
        run_scenario, servers, SERVICE, sc, policy="priority",
        classes=list(classes), class_rates=[1.3, 0.7], aging_rate=0.001,
        admission_level=0.8, seed=3)
    spec = api.ExperimentSpec(
        cluster=api.ClusterSpec(servers=servers, service=SERVICE),
        scenario=api.ScenarioSpec.from_scenario(sc),
        workload=api.WorkloadSpec(class_rates=(1.3, 0.7), classes=classes),
        policy=api.PolicySpec(name="priority", aging_rate=0.001),
        admission=api.AdmissionSpec(level=0.8),
        seed=3)
    rep = api.run(spec)
    assert np.array_equal(old.result.response_times,
                          rep.raw.result.response_times)
    assert old.n_rejected == rep.n_rejected
    assert old.per_class().keys() == rep.raw.per_class().keys()


def test_controller_run_bit_identical_via_spec():
    """A spec-built controller reproduces an externally-built identical
    controller bit for bit (same telemetry, same decisions, same events)."""
    from repro.autoscale import (
        AutoscaleController, ControllerConfig, PredictivePolicy, Telemetry,
        TelemetryConfig,
    )

    servers = (Server("b0", TEMPLATE.memory_gb, TEMPLATE.tau_c,
                      TEMPLATE.tau_p),)
    sc = Scenario(horizon=150.0)
    ctl = AutoscaleController(
        PredictivePolicy(TEMPLATE, lead=20.0, margin=1.2), TEMPLATE,
        ControllerConfig(interval=5.0, cooldown=20.0, warmup_lag=10.0,
                         min_servers=1, max_servers=12,
                         slo_response_time=3.0),
        telemetry=Telemetry(TelemetryConfig(window=20.0)))
    old = no_deprecation(
        run_scenario, servers, SERVICE, sc, base_rate=4.0, seed=0,
        controller=ctl)
    spec = api.ExperimentSpec(
        cluster=api.ClusterSpec(servers=servers, service=SERVICE),
        scenario=api.ScenarioSpec.from_scenario(sc),
        workload=api.WorkloadSpec(base_rate=4.0),
        autoscale=api.AutoscaleSpec(
            policy="predictive", template=TEMPLATE,
            params={"lead": 20.0, "margin": 1.2},
            interval=5.0, cooldown=20.0, warmup_lag=10.0,
            min_servers=1, max_servers=12, slo_response_time=3.0,
            telemetry_window=20.0),
        seed=0)
    rep = api.run(spec)
    assert np.array_equal(old.result.response_times,
                          rep.raw.result.response_times)
    assert [dataclasses.asdict(e) for e in old.log] == rep.events
    assert rep.cost is not None and rep.cost["policy"] == "predictive"
    assert rep.cost["server_seconds"] == pytest.approx(ctl.server_seconds)


def test_orchestrator_shim_matches_drive_orchestrator():
    from repro.serving import Request, mock_orchestrator

    def build():
        orch = mock_orchestrator(
            [Server(f"b{i}", 16.0, 0.05, 0.08) for i in range(3)], SERVICE,
            arrival_rate=1.0)
        reqs = [(0.5 * i, Request(rid=i, prompt=np.ones(4, np.int32),
                                  max_new_tokens=5, arrival_time=0.5 * i))
                for i in range(20)]
        return orch, reqs

    orch_a, reqs_a = build()
    sc = Scenario(horizon=30.0).fail(5.0, "b0").recover(10.0, orch_a.servers
                                                        .get("b0")
                                                        or Server("b0", 16.0,
                                                                  0.05, 0.08))
    with pytest.warns(DeprecationWarning):
        old = orch_a.run_scenario(sc, reqs_a, dt=0.5)
    orch_b, reqs_b = build()
    new = api.drive_orchestrator(orch_b, sc, reqs_b, dt=0.5)
    assert old["finished"] == new["finished"] == 20
    assert old["rounds"] == new["rounds"]
    assert [r[1].output for r in reqs_a] == [r[1].output for r in reqs_b]


# ---------------------------------------------------------------------------
# Plane agnosticism
# ---------------------------------------------------------------------------

def test_same_spec_runs_on_both_planes():
    servers = cluster(6)
    spec = api.ExperimentSpec(
        cluster=api.ClusterSpec(servers=servers, service=SERVICE),
        scenario=api.ScenarioSpec.from_scenario(
            Scenario(horizon=60.0).fail(20.0, "s3")
            .recover(40.0, servers[3])),
        workload=api.WorkloadSpec(base_rate=2.0),
        seed=0, name="both-planes")
    rep_sim = api.run(spec, plane="sim")
    rep_live = api.run(spec, plane=api.LivePlane(dt=0.5))
    assert rep_sim.plane == "sim" and rep_live.plane == "live"
    assert rep_sim.completed_all and rep_live.completed_all
    assert rep_sim.n_jobs == rep_live.n_jobs      # same resolved workload
    diff = rep_sim.diff(rep_live)
    assert diff["plane"] == ("sim", "live")
    assert "n_jobs" not in diff
    # both reports serialize
    json.dumps(rep_sim.to_dict())
    json.dumps(rep_live.to_dict())


def test_live_plane_multitenant_defers_only_batch():
    classes = (RequestClass("interactive", "chat", 0, slo_target=2.0),
               RequestClass("batch", "offline", 1, deadline=2.0))
    spec = api.ExperimentSpec(
        cluster=api.ClusterSpec(
            servers=(Server("b0", 16.0, 0.05, 0.08),), service=SERVICE),
        scenario=api.ScenarioSpec(horizon=40.0),
        workload=api.WorkloadSpec(class_rates=(2.0, 2.0), classes=classes),
        policy=api.PolicySpec(name="priority", aging_rate=0.001),
        seed=1)
    rep = api.run(spec, plane=api.LivePlane(dt=0.5))
    assert rep.per_class, "live plane must report per-class stats"
    assert set(rep.per_class) == {0, 1}
    assert rep.per_class[0]["name"] == "interactive"
    assert rep.n_completed + rep.n_rejected + rep.n_failed == rep.n_jobs


def test_idle_fast_forward_skips_sparse_gaps():
    """A 200 s silence between two requests costs ~0 rounds when nothing is
    in flight — and the outcome is identical to the spin-every-dt drive
    (reconstructed by installing a no-op step hook, which disables the
    fast-forward)."""
    from repro.serving import Request, mock_orchestrator

    def build(hook: bool):
        orch = mock_orchestrator([Server("b0", 16.0, 0.05, 0.08)], SERVICE,
                                 arrival_rate=1.0)
        if hook:
            orch.step_hooks.append(lambda o, now: None)
        reqs = [(0.0, Request(rid=0, prompt=np.ones(4, np.int32),
                              max_new_tokens=4)),
                (200.0, Request(rid=1, prompt=np.ones(4, np.int32),
                                max_new_tokens=4, arrival_time=200.0))]
        return orch, reqs

    orch_fast, reqs_fast = build(hook=False)
    fast = api.drive_orchestrator(orch_fast, Scenario(horizon=250.0),
                                  reqs_fast, dt=0.5)
    orch_slow, reqs_slow = build(hook=True)
    slow = api.drive_orchestrator(orch_slow, Scenario(horizon=250.0),
                                  reqs_slow, dt=0.5)
    assert fast["finished"] == slow["finished"] == 2
    assert fast["idle_skipped"] > 300          # ~200 s / 0.5 s of silence
    assert slow["idle_skipped"] == 0
    assert fast["rounds"] == slow["rounds"]    # same t = rounds*dt grid
    assert [r[1].output for r in reqs_fast] \
        == [r[1].output for r in reqs_slow]
    # events/warm-ups are still honored on the fast path at the same grid
    # times, so response times agree exactly
    assert [r[1].response_time() for r in reqs_fast] \
        == [r[1].response_time() for r in reqs_slow]


# ---------------------------------------------------------------------------
# Sweeps
# ---------------------------------------------------------------------------

def test_sweep_grid_is_deterministic_and_seeded():
    spec = base_spec(horizon=80.0)
    grid = {"policy.name": ["jffc", "sed"], "seed": [0, 1]}
    pts = api.sweep(spec, grid)
    assert len(pts) == 4
    assert [p.overrides for p in pts] == [
        {"policy.name": "jffc", "seed": 0},
        {"policy.name": "jffc", "seed": 1},
        {"policy.name": "sed", "seed": 0},
        {"policy.name": "sed", "seed": 1},
    ]
    # each point reproduces a direct run of its own spec exactly
    for p in pts:
        direct = api.run(p.spec)
        assert np.array_equal(direct.raw.result.response_times,
                              p.report.raw.result.response_times)
    # re-running the sweep reproduces itself
    again = api.sweep(spec, grid)
    for a, b in zip(pts, again):
        assert np.array_equal(a.report.raw.result.response_times,
                              b.report.raw.result.response_times)


def test_spec_replace_nested_paths_and_errors():
    spec = base_spec()
    out = api.spec_replace(spec, "workload.base_rate", 5.0)
    assert out.workload.base_rate == 5.0 and spec.workload.base_rate == 3.0
    out = api.spec_replace(spec, "seed", 9)
    assert out.seed == 9
    with pytest.raises(api.SpecError, match="no such field"):
        api.spec_replace(spec, "workload.nope", 1)
    with pytest.raises(api.SpecError):        # replace re-validates
        api.spec_replace(spec, "policy.name", "nosuch")


# ---------------------------------------------------------------------------
# Registries: third-party extension with zero core edits
# ---------------------------------------------------------------------------

def test_workload_generator_registers_by_decorator():
    name = "test-burst-pair"
    try:
        @api.WORKLOADS.register(name)
        def _gen(workload, scenario, seed):
            t = np.array([1.0, 2.0])
            return t, np.ones(2)

        spec = base_spec(workload=api.WorkloadSpec(generator=name,
                                                   base_rate=1.0))
        rep = api.run(spec)
        assert rep.n_jobs == 2 and rep.completed_all
    finally:
        api.WORKLOADS._entries.pop(name, None)


def test_event_kind_registry_writes_through_to_core():
    name = "chaos-monkey"
    assert name not in core_scenarios.EVENT_KINDS
    try:
        api.EVENT_KINDS.register(name, None)
        assert name in core_scenarios.EVENT_KINDS
        # ScenarioEvent now accepts the new kind with no core edits
        ev = ScenarioEvent(1.0, name)
        assert ev.kind == name
    finally:
        api.EVENT_KINDS._entries.pop(name, None)
        if name in core_scenarios.EVENT_KINDS:
            core_scenarios.EVENT_KINDS.remove(name)


def test_dispatch_policy_registry_writes_through_to_core():
    from repro.core.load_balance import POLICIES

    name = "test-policy"
    try:
        api.DISPATCH_POLICIES.register(name, object)
        assert POLICIES[name] is object
        assert api.PolicySpec(name=name).name == name
    finally:
        api.DISPATCH_POLICIES._entries.pop(name, None)
        POLICIES.pop(name, None)


def test_tuner_registry_writes_through_to_compose():
    from repro.core.tuning import TUNERS, compose

    name = "test-fixed-c"
    calls = []
    try:
        @api.TUNERS.register(name)
        def _tuner(servers, spec, lam, rho_bar):
            calls.append(lam)
            return TUNERS["bound-lower"](servers, spec, lam, rho_bar)

        c, pl, alloc = compose(list(cluster()), SERVICE, 2.0, 0.7,
                               tuner=name)
        assert calls == [2.0] and alloc.total_rate > 0
        # and the spec layer validates it
        api.ClusterSpec(servers=cluster(), service=SERVICE, tuner=name)
    finally:
        api.TUNERS._entries.pop(name, None)
        TUNERS.pop(name, None)


def test_unknown_plane_lists_known_names():
    with pytest.raises(api.UnknownNameError, match="sim"):
        api.get_plane("warp")


# ---------------------------------------------------------------------------
# Review regressions
# ---------------------------------------------------------------------------

def test_registry_reregistration_wins_in_core_too():
    """Latest-wins must propagate through the write-through: stubbing a
    builtin tuner on the API registry changes what ``compose`` runs."""
    from repro.core.tuning import TUNERS, compose

    original = TUNERS["bound-lower"]
    calls = []

    def stub(servers, spec, lam, rho_bar):
        calls.append(lam)
        return original(servers, spec, lam, rho_bar)

    try:
        api.TUNERS.register("bound-lower", stub)
        assert TUNERS["bound-lower"] is stub
        compose(list(cluster()), SERVICE, 2.0, 0.7, tuner="bound-lower")
        assert calls == [2.0]
    finally:
        api.TUNERS.register("bound-lower", original)
    assert TUNERS["bound-lower"] is original


def test_live_plane_rejects_unimplemented_policies():
    spec = base_spec(policy=api.PolicySpec(name="sed"))
    with pytest.raises(api.SpecError, match="policy.name"):
        api.run(spec, plane=api.LivePlane())
    # sim plane runs it fine
    assert api.run(spec).completed_all


def test_live_plane_honors_warmup_fraction():
    spec = base_spec(cluster(6), horizon=60.0,
                     workload=api.WorkloadSpec(base_rate=2.0),
                     scenario=api.ScenarioSpec(horizon=60.0))
    full = api.run(spec, plane=api.LivePlane(dt=0.5))
    trimmed = api.run(spec.replace(warmup_fraction=0.5),
                      plane=api.LivePlane(dt=0.5))
    assert trimmed.completed_all           # judged on untrimmed counts
    assert trimmed.n_jobs == full.n_jobs
    assert trimmed.n_completed == full.n_completed \
        - int(full.n_completed * 0.5)
    assert trimmed.response["mean"] != full.response["mean"]


def test_arrivals_override_accepts_rows_as_tuple_or_list():
    rows = [(0.5, 1.0, 0, 0), (1.0, 0.5, 0, 0), (1.5, 2.0, 0, 0)]
    spec = api.ExperimentSpec(
        cluster=api.ClusterSpec(job_servers=((1.0, 1),)),
        scenario=api.ScenarioSpec(horizon=10.0),
        workload=api.WorkloadSpec(base_rate=1.0), seed=0)
    as_list = api.run(spec, arrivals=rows)
    as_tuple = api.run(spec, arrivals=tuple(rows))
    assert as_list.n_jobs == as_tuple.n_jobs == 3
    assert np.array_equal(as_list.raw.result.response_times,
                          as_tuple.raw.result.response_times)
    with pytest.raises(api.SpecError, match="arrivals"):
        api.run(spec, arrivals=(0.5, 1.0))   # scalars are neither form


# ---------------------------------------------------------------------------
# Simulation backends through the spec (PR 5)
# ---------------------------------------------------------------------------

def test_engine_field_round_trips_and_validates():
    spec = api.ExperimentSpec(
        cluster=api.ClusterSpec(job_servers=JOB_SERVERS, engine="batched"),
        scenario=api.ScenarioSpec(horizon=50.0),
        workload=api.WorkloadSpec(generator="poisson", base_rate=4.0,
                                  params={"n": 100}))
    back = api.ExperimentSpec.from_json(spec.to_json())
    assert back == spec and back.cluster.engine == "batched"
    # pre-engine-field records (no "engine" key) read as the default
    d = spec.to_dict()
    del d["cluster"]["engine"]
    assert api.ExperimentSpec.from_dict(d).cluster.engine == "vector"
    with pytest.raises(api.SpecError, match="cluster.engine"):
        api.ClusterSpec(job_servers=JOB_SERVERS, engine="warp")


def test_engine_choice_is_result_invariant():
    """engine='batched' must reproduce engine='vector' bit for bit through
    the full spec path (composed cluster + scripted events included)."""
    servers = cluster(6)
    sc = scripted_scenario(servers, horizon=150.0)
    reports = {}
    for engine in api.ENGINES:
        spec = api.ExperimentSpec(
            cluster=api.ClusterSpec(servers=servers, service=SERVICE,
                                    engine=engine),
            scenario=api.ScenarioSpec.from_scenario(sc),
            workload=api.WorkloadSpec(base_rate=3.0), seed=0)
        reports[engine] = api.run(spec)
    a, b = reports["vector"], reports["batched"]
    assert not {k: v for k, v in a.diff(b).items()}, a.diff(b)
    assert np.array_equal(a.raw.result.response_times,
                          b.raw.result.response_times)


def test_build_simulator_honors_engine():
    from repro.core.engines import BatchedEngine, VectorEngine

    spec = api.ExperimentSpec(
        cluster=api.ClusterSpec(job_servers=JOB_SERVERS, engine="batched"),
        scenario=api.ScenarioSpec(horizon=100.0),
        workload=api.WorkloadSpec(generator="poisson", base_rate=4.0,
                                  params={"n": 500}))
    assert isinstance(api.build_simulator(spec), BatchedEngine)
    assert isinstance(
        api.build_simulator(
            api.spec_replace(spec, "cluster.engine", "vector")),
        VectorEngine)


def test_sweep_engine_override_and_parity():
    """sweep(engine=...) rewrites every point's engine; batched and vector
    sweeps agree bit for bit whether or not the one-pass fast path ran."""
    spec = api.ExperimentSpec(
        cluster=api.ClusterSpec(job_servers=JOB_SERVERS),
        scenario=api.ScenarioSpec(horizon=300.0),
        workload=api.WorkloadSpec(generator="poisson", base_rate=8.0,
                                  params={"n": 2500}),
        seed=0, warmup_fraction=0.1)
    seeds = [0, 1, 2, 3]
    fast = api.sweep(spec, {"seed": seeds}, engine="batched")
    slow = api.sweep(spec, {"seed": seeds}, engine="vector")
    assert [p.spec.cluster.engine for p in fast] == ["batched"] * 4
    for pf, ps in zip(fast, slow):
        assert pf.overrides == ps.overrides
        assert np.array_equal(pf.report.raw.result.response_times,
                              ps.report.raw.result.response_times)
        assert pf.report.completed_all


def test_sweep_one_pass_only_when_eligible():
    """Grids that cannot run compiled (legacy-scheme RNG policies) must
    take the per-point path and still agree with per-point runs;
    multi-policy deterministic grids now stack into the one-pass path."""
    from repro.core.engines import jax_available

    spec = api.ExperimentSpec(
        cluster=api.ClusterSpec(job_servers=JOB_SERVERS, engine="batched"),
        scenario=api.ScenarioSpec(horizon=200.0),
        workload=api.WorkloadSpec(generator="poisson", base_rate=8.0,
                                  params={"n": 1500}),
        seed=0)
    # legacy scheme + an RNG-consuming policy: sequential fallback
    pts = api.sweep(spec, {"policy.name": ["jffc", "random"]})
    assert not any(p.report.extras.get("swept_one_pass") for p in pts)
    for p in pts:
        solo = api.run(p.spec)
        assert np.array_equal(p.report.raw.result.response_times,
                              solo.raw.result.response_times)
    if jax_available():
        # deterministic multi-policy grids stack (PR 6), seeds always did
        one = api.sweep(spec, {"policy.name": ["jffc", "sed"],
                               "seed": [0, 1]})
        assert all(p.report.extras.get("swept_one_pass") for p in one)
        for p in one:
            solo = api.run(p.spec)
            assert np.array_equal(p.report.raw.result.response_times,
                                  solo.raw.result.response_times)


# ---------------------------------------------------------------------------
# Results store (PR 5)
# ---------------------------------------------------------------------------

def _store_spec(seed=0):
    return api.ExperimentSpec(
        cluster=api.ClusterSpec(job_servers=JOB_SERVERS),
        scenario=api.ScenarioSpec(horizon=100.0),
        workload=api.WorkloadSpec(generator="poisson", base_rate=6.0,
                                  params={"n": 800}),
        seed=seed, warmup_fraction=0.1, name="store-test")


def test_results_store_hit_and_mutation_miss(tmp_path):
    store = api.ResultsStore(str(tmp_path / "cache"))
    spec = _store_spec()
    first = api.run(spec, store=store)
    assert (store.hits, len(store)) == (0, 1)
    second = api.run(spec, store=store)          # identical spec: cache hit
    assert store.hits == 1 and len(store) == 1
    assert second.raw is None                    # served from disk
    assert second.response == first.response
    assert second.n_completed == first.n_completed
    assert not first.diff(second)
    mutated = api.run(spec.replace(seed=1), store=store)   # any change: miss
    assert store.hits == 1 and len(store) == 2
    assert mutated.response != first.response


def test_results_store_keys_on_plane_and_engine(tmp_path):
    store = api.ResultsStore(str(tmp_path))
    spec = _store_spec()
    api.run(spec, store=store)
    # same spec, different engine -> different key -> miss
    api.run(api.spec_replace(spec, "cluster.engine", "batched"),
            store=store)
    assert store.hits == 0 and len(store) == 2
    assert api.spec_key(spec, "sim", "vector") \
        != api.spec_key(spec, "live", "vector")
    assert api.spec_key(spec, "sim", "vector") \
        != api.spec_key(spec, "sim", "batched")


def test_results_store_bypassed_by_escape_hatches(tmp_path):
    store = api.ResultsStore(str(tmp_path))
    spec = _store_spec()
    rows = [(0.5, 1.0, 0, 0), (1.0, 0.5, 0, 0)]
    api.run(spec, arrivals=rows, store=store)
    assert len(store) == 0                       # not a function of the spec


def test_run_report_from_dict_round_trip():
    rep = api.run(_store_spec())
    back = api.RunReport.from_dict(rep.to_dict())
    assert back.response == rep.to_dict()["response"]
    assert back.per_class.keys() == rep.per_class.keys()
    assert not rep.diff(back)
    with pytest.raises(ValueError, match="unknown RunReport fields"):
        api.RunReport.from_dict({**rep.to_dict(), "bogus": 1})


# ---------------------------------------------------------------------------
# Experiment presets (PR 5)
# ---------------------------------------------------------------------------

def test_presets_registry_builds_valid_specs():
    assert set(api.PRESETS.names()) >= {"diurnal_autoscale",
                                        "overloaded_70_30",
                                        "failover_burst"}
    for name in api.PRESETS:
        spec = api.preset(name)
        assert isinstance(spec, api.ExperimentSpec)
        # every preset round-trips (it is an ExperimentSpec like any other)
        assert api.ExperimentSpec.from_json(spec.to_json()) == spec


def test_preset_knobs_and_unknown_name():
    spec = api.preset("overloaded_70_30", policy="jffc", aging_rate=0.0,
                      batch_deadline=math.inf, name="fifo-leg")
    assert spec.policy.name == "jffc" and spec.name == "fifo-leg"
    assert spec.workload.classes[1].deadline == math.inf
    with pytest.raises(api.UnknownNameError, match="experiment preset"):
        api.preset("no-such-preset")


def test_failover_burst_preset_runs_clean():
    rep = api.run(api.preset("failover_burst", n_target=1_500))
    assert rep.completed_all
    assert rep.reconfigurations == 2             # fail + recover
    kinds = [e["kind"] for e in rep.events]
    assert kinds == ["fail", "add"]


def test_results_store_keys_on_plane_configuration(tmp_path):
    """Two differently configured planes must never share a cache entry
    (a LivePlane(dt=2.0) report is not a LivePlane(dt=0.25) report)."""
    store = api.ResultsStore(str(tmp_path))
    spec = base_spec(cluster(6), horizon=60.0,
                     workload=api.WorkloadSpec(base_rate=2.0),
                     scenario=api.ScenarioSpec(horizon=60.0))
    coarse = api.run(spec, plane=api.LivePlane(dt=2.0), store=store)
    fine = api.run(spec, plane=api.LivePlane(dt=0.25), store=store)
    assert store.hits == 0 and len(store) == 2
    assert coarse.sim_time != fine.sim_time
    # same configuration: a hit
    again = api.run(spec, plane=api.LivePlane(dt=2.0), store=store)
    assert store.hits == 1 and again.sim_time == coarse.sim_time


def test_results_store_bypassed_without_store_key(tmp_path):
    """A plane that does not declare a store_key is never cached."""
    class OpaquePlane:
        name = "opaque"

        def run(self, spec, *, arrivals=None, controller=None):
            return api.run(spec)          # delegate, identity unknown

    store = api.ResultsStore(str(tmp_path))
    rep = api.run(_store_spec(), plane=OpaquePlane(), store=store)
    assert rep.completed_all and len(store) == 0


def test_sweep_late_fallback_reuses_traces_and_matches_per_point():
    """A batched-engine grid whose traces cannot stack (the horizon-driven
    'scenario' generator gives each seed a different job count) must fall
    back to sequential execution with results identical to plain runs."""
    spec = api.ExperimentSpec(
        cluster=api.ClusterSpec(job_servers=JOB_SERVERS, engine="batched"),
        scenario=api.ScenarioSpec(horizon=400.0),
        workload=api.WorkloadSpec(base_rate=6.0),       # scenario-generated
        seed=0, warmup_fraction=0.1)
    pts = api.sweep(spec, {"seed": [0, 1, 2]})
    assert not any(p.report.extras.get("swept_one_pass") for p in pts)
    lens = {p.report.n_jobs for p in pts}
    assert len(lens) > 1                   # the reason it could not stack
    for p in pts:
        solo = api.run(p.spec)
        assert np.array_equal(p.report.raw.result.response_times,
                              solo.raw.result.response_times)


def test_failover_burst_preset_validates_fleet_size():
    with pytest.raises(api.SpecError, match="n_servers"):
        api.preset("failover_burst", n_servers=3)
    api.preset("failover_burst", n_servers=4)   # smallest valid fleet


def test_results_store_live_plane_ignores_sim_engine(tmp_path):
    """cluster.engine is sim-only: live-plane runs of its engine variants
    share one cache entry (same experiment, no silent re-execution)."""
    store = api.ResultsStore(str(tmp_path))
    spec = base_spec(cluster(6), horizon=60.0,
                     workload=api.WorkloadSpec(base_rate=2.0),
                     scenario=api.ScenarioSpec(horizon=60.0))
    api.run(spec, plane=api.LivePlane(dt=1.0), store=store)
    hit = api.run(api.spec_replace(spec, "cluster.engine", "batched"),
                  plane=api.LivePlane(dt=1.0), store=store)
    assert store.hits == 1 and len(store) == 1
    assert hit.plane == "live"
    # rng_scheme is likewise sim-only: its variants share the entry too
    api.run(api.spec_replace(spec, "rng_scheme", "counter"),
            plane=api.LivePlane(dt=1.0), store=store)
    assert store.hits == 2 and len(store) == 1


# ---------------------------------------------------------------------------
# Counter-based policy RNG through the spec (PR 6)
# ---------------------------------------------------------------------------

def _grid_spec(rng_scheme="legacy", n=1500):
    return api.ExperimentSpec(
        cluster=api.ClusterSpec(job_servers=JOB_SERVERS, engine="batched"),
        scenario=api.ScenarioSpec(horizon=400.0),
        workload=api.WorkloadSpec(generator="poisson", base_rate=8.0,
                                  params={"n": n}),
        seed=0, warmup_fraction=0.1, rng_scheme=rng_scheme)


def test_rng_scheme_round_trips_and_validates():
    spec = _grid_spec("counter")
    back = api.ExperimentSpec.from_json(spec.to_json())
    assert back == spec and back.rng_scheme == "counter"
    # pre-scheme-field records (no "rng_scheme" key) read as legacy
    d = spec.to_dict()
    del d["rng_scheme"]
    assert api.ExperimentSpec.from_dict(d).rng_scheme == "legacy"
    with pytest.raises(api.SpecError, match="rng_scheme"):
        _grid_spec("philox")
    # a different scheme is a different experiment: replace re-validates
    assert api.spec_replace(spec, "rng_scheme", "legacy") != spec


def test_spec_rng_scheme_reaches_both_engines():
    """The spec field must actually change RNG-policy trajectories (the
    schemes draw differently) on either backend."""
    spec = api.spec_replace(_grid_spec("legacy", n=800),
                            "policy.name", "random")
    for engine in ("vector", "batched"):
        s = api.spec_replace(spec, "cluster.engine", engine)
        legacy = api.run(s)
        counter = api.run(api.spec_replace(s, "rng_scheme", "counter"))
        assert not np.array_equal(legacy.raw.result.response_times,
                                  counter.raw.result.response_times)


def test_sweep_counter_policy_grid_one_pass_matches_sequential():
    """The tentpole gate at the API level: a full policy×seed grid under
    the counter scheme runs one-pass on the batched engine and matches
    the sequential vector-engine replay bit for bit."""
    from repro.core.engines import jax_available

    if not jax_available():
        pytest.skip("jax required for the one-pass grid")
    grid = {"policy.name": list(VECTORIZED_POLICIES), "seed": [0, 3]}
    fast = api.sweep(_grid_spec("counter"), grid)
    assert all(p.report.extras.get("swept_one_pass") for p in fast)
    slow = api.sweep(_grid_spec("counter"), grid, engine="vector")
    for pf, ps in zip(fast, slow):
        assert pf.overrides == ps.overrides
        assert np.array_equal(pf.report.raw.result.response_times,
                              ps.report.raw.result.response_times)
        assert pf.report.sim_time == ps.report.sim_time


def test_sweep_store_threads_both_paths(tmp_path):
    """sweep(store=) caches every point on the one-pass path and the
    sequential path alike; a re-sweep is all hits, and one-pass entries
    are directly reusable by per-point run()s (bit-identical results)."""
    from repro.core.engines import jax_available

    grid = {"policy.name": ["jffc", "sed"], "seed": [0, 1]}
    # sequential path (vector engine)
    store = api.ResultsStore(str(tmp_path / "seq"))
    spec = api.spec_replace(_grid_spec(), "cluster.engine", "vector")
    api.sweep(spec, grid, store=store)
    assert store.hits == 0 and len(store) == 4
    api.sweep(spec, grid, store=store)
    assert store.hits == 4 and len(store) == 4
    if not jax_available():
        return
    # one-pass path (batched engine)
    store = api.ResultsStore(str(tmp_path / "fast"))
    pts = api.sweep(_grid_spec(), grid, store=store)
    assert all(p.report.extras.get("swept_one_pass") for p in pts)
    assert store.hits == 0 and len(store) == 4
    again = api.sweep(_grid_spec(), grid, store=store)
    assert store.hits == 4 and len(store) == 4
    for a, b in zip(pts, again):
        assert a.report.response == b.report.response
    # a per-point run shares the cache entry the one-pass sweep wrote
    solo = api.run(pts[1].spec, store=store)
    assert store.hits == 5
    assert solo.response == pts[1].report.response


def test_warmup_default_matches_spec_default():
    """Regression pin (PR 6): EngineCore.result() defaulted to 0.1 while
    ExperimentSpec.warmup_fraction defaults to 0.0 — a bare result() call
    must now keep every completion, exactly like the spec path."""
    from repro.core.engines import make_engine
    from repro.core.workload import poisson_exponential_np

    assert api.ExperimentSpec.__dataclass_fields__[
        "warmup_fraction"].default == 0.0
    t, w = poisson_exponential_np(5.0, 400, seed=2)
    sim = make_engine("vector", [m for m, _ in JOB_SERVERS],
                      [c for _, c in JOB_SERVERS])
    sim.add_arrivals(t, w)
    sim.run_to_completion()
    assert sim.result().n_completed == sim.result(0.0).n_completed == 400
    spec = _grid_spec(n=400)                    # warmup_fraction spec'd 0.1
    rep = api.run(api.spec_replace(spec, "warmup_fraction", 0.0))
    assert rep.n_completed == 400
