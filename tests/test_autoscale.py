"""Autoscaling control plane: telemetry estimators, policy decisions,
controller invariants (cooldown, warm-up, cost accounting), closed-loop
runs on both execution planes, and the new scenario-engine features that
ride along (correlated fail_group, token-based service times).

Everything here is numpy-only — no jax — so the whole module runs in the
minimal-dependency environment.
"""
import math

import numpy as np
import pytest

from conftest import run_scenario_spec as run_scenario
from repro import api
from repro.core import (
    Scenario,
    ScenarioEvent,
    Server,
    ServiceSpec,
    azure_like_trace_np,
    diurnal_phases,
    diurnal_poisson,
    token_work,
    trace_replay_phases,
)
from repro.autoscale import (
    AutoscaleAction,
    AutoscaleController,
    AutoscalePolicy,
    ClusterView,
    ControllerConfig,
    PredictivePolicy,
    QueueGradientPolicy,
    TargetUtilizationPolicy,
    Telemetry,
    TelemetryConfig,
    composition_feasible,
    servers_needed,
    static_baseline_cost,
)
from repro.serving import Request, State, mock_orchestrator

SPEC = ServiceSpec(num_blocks=10, block_size_gb=1.32, cache_size_gb=0.11)


def mk(sid, mem=16.0, tc=0.05, tp=0.08):
    return Server(sid, mem, tc, tp)


TEMPLATE = mk("template")


def make_controller(policy, *, interval=5.0, cooldown=20.0, warmup_lag=10.0,
                    min_servers=1, max_servers=40, slo=3.0, window=20.0):
    return AutoscaleController(
        policy, TEMPLATE,
        ControllerConfig(interval=interval, cooldown=cooldown,
                         warmup_lag=warmup_lag, min_servers=min_servers,
                         max_servers=max_servers, slo_response_time=slo),
        telemetry=Telemetry(TelemetryConfig(window=window)))


# ---------------------------------------------------------------------------
# Telemetry estimators
# ---------------------------------------------------------------------------

def test_telemetry_window_rate_and_ewma():
    tel = Telemetry(TelemetryConfig(window=10.0, ewma_alpha=0.5))
    tel.record_arrivals(np.arange(0.0, 10.0, 0.5))      # 2 jobs/s
    tel.record_sample(10.0, queue_depth=0, in_flight=1, capacity=4,
                      n_servers=1)
    assert tel.arrival_rate_window() == pytest.approx(2.0, rel=0.1)
    assert tel.arrival_rate() == pytest.approx(2.0, rel=0.1)
    assert tel.utilization() == pytest.approx(0.25)


def test_telemetry_window_slides():
    tel = Telemetry(TelemetryConfig(window=5.0))
    tel.record_arrivals(np.linspace(0.0, 4.9, 50))      # 10/s burst
    tel.record_sample(5.0, 0, 0, 4, 1)
    burst = tel.arrival_rate_window()
    tel.record_arrival(12.0)                            # quiet period
    tel.record_sample(12.0, 0, 0, 4, 1)
    assert tel.arrival_rate_window() < burst / 5


def test_telemetry_trend_and_forecast():
    tel = Telemetry(TelemetryConfig(window=20.0))
    # steadily rising rate, sampled every 2 s as a controller would: the
    # trend must be positive and the forecast above the current estimate
    t, rate, next_sample = 0.0, 2.0, 2.0
    while t < 40.0:
        t += 1.0 / rate
        tel.record_arrival(t)
        rate += 0.05
        if t >= next_sample:
            tel.record_sample(t, 0, 0, 4, 1)
            next_sample += 2.0
    assert tel.rate_trend() > 0
    assert tel.forecast_rate(20.0) > tel.arrival_rate()


def test_telemetry_queue_gradient_sign():
    tel = Telemetry(TelemetryConfig(window=30.0))
    for i, q in enumerate((0, 2, 5, 9, 14)):
        tel.record_sample(5.0 * (i + 1), queue_depth=q, in_flight=4,
                          capacity=4, n_servers=2)
    assert tel.queue_gradient() > 0
    assert tel.queue_depth() == 14


def test_telemetry_response_quantiles():
    tel = Telemetry()
    for i in range(100):
        tel.record_completion(1.0 + 0.01 * i, response_time=float(i))
    assert tel.response_quantile(50) == pytest.approx(49.5, abs=1.0)
    assert math.isnan(Telemetry().response_quantile(99))


# ---------------------------------------------------------------------------
# Sizing oracle + policies
# ---------------------------------------------------------------------------

def test_servers_needed_monotone_in_rate():
    needs = [servers_needed([], TEMPLATE, SPEC, rate, 0.7, max_extra=40)
             for rate in (1.0, 5.0, 10.0, 15.0)]
    assert all(n is not None for n in needs)
    assert needs == sorted(needs)
    assert needs[0] >= 1 and needs[-1] > needs[0]


def test_composition_feasible_boundaries():
    assert not composition_feasible([], SPEC, 1.0, 0.7)
    assert composition_feasible([mk("a"), mk("b")], SPEC, 1.0, 0.7)
    assert not composition_feasible([mk("a")], SPEC, 1e6, 0.7)


def _view(servers, pending=(), total_rate=10.0):
    return ClusterView(servers=list(servers), pending=list(pending),
                       spec=SPEC, rho_bar=0.7, total_rate=total_rate)


def test_target_util_policy_thresholds():
    pol = TargetUtilizationPolicy(high=0.8, low=0.3)
    tel = Telemetry()
    tel.record_sample(1.0, queue_depth=0, in_flight=9, capacity=10,
                      n_servers=3)
    act = pol.decide(tel, _view([mk("a"), mk("b"), mk("c")]), 1.0)
    assert act.add >= 1 and act.remove == 0
    tel2 = Telemetry()
    tel2.record_sample(1.0, queue_depth=0, in_flight=1, capacity=10,
                       n_servers=3)
    act = pol.decide(tel2, _view([mk("a"), mk("b"), mk("c")]), 1.0)
    assert act.remove == 1 and act.add == 0
    tel3 = Telemetry()
    tel3.record_sample(1.0, queue_depth=0, in_flight=5, capacity=10,
                       n_servers=3)
    assert pol.decide(tel3, _view([mk("a"), mk("b"), mk("c")]), 1.0).is_noop


def test_queue_gradient_policy_reacts_to_growth():
    pol = QueueGradientPolicy(depth_threshold=3)
    tel = Telemetry(TelemetryConfig(window=30.0))
    for i, q in enumerate((0, 4, 9, 15, 22)):
        tel.record_sample(5.0 * (i + 1), queue_depth=q, in_flight=8,
                          capacity=8, n_servers=2)
    act = pol.decide(tel, _view([mk("a"), mk("b")]), 25.0)
    assert act.add >= 1


def test_predictive_policy_sizes_through_oracle():
    pol = PredictivePolicy(TEMPLATE, lead=20.0, margin=1.2)
    tel = Telemetry(TelemetryConfig(window=40.0))
    t, rate = 0.0, 4.0
    while t < 40.0:
        t += 1.0 / rate
        tel.record_arrival(t)
        rate += 0.02
    for s in np.arange(20.0, 41.0, 5.0):
        tel.record_sample(s, 0, 4, 4, 1)
    act = pol.decide(tel, _view([mk("a")]), 40.0)
    assert act.add >= 1                      # one server cannot hold ~6/s


# ---------------------------------------------------------------------------
# Controller invariants
# ---------------------------------------------------------------------------

class AlwaysAdd(AutoscalePolicy):
    name = "always-add"

    def decide(self, tel, view, now):
        return AutoscaleAction(add=1, reason="test")


def test_cooldown_respected_no_churn():
    """No two scaling actions within the cooldown window, ever."""
    ctl = make_controller(AlwaysAdd(), interval=5.0, cooldown=22.0)
    arrivals = diurnal_poisson(6.0, 300.0, amplitude=0.5, seed=1)
    run_scenario([mk("b0")], SPEC, Scenario(horizon=300.0), base_rate=6.0,
                 arrivals=arrivals, controller=ctl, seed=0)
    times = [rec.time for rec in ctl.records]
    assert len(times) >= 2                   # the greedy policy acted often
    gaps = np.diff(times)
    assert np.all(gaps >= 22.0 - 1e-9), gaps


def test_warmup_lag_delays_joining():
    """A provisioned server joins the composition exactly one warm-up lag
    after the add decision — never earlier."""
    ctl = make_controller(AlwaysAdd(), interval=5.0, cooldown=30.0,
                          warmup_lag=12.0)
    arrivals = diurnal_poisson(6.0, 200.0, amplitude=0.5, seed=1)
    run_scenario([mk("b0")], SPEC, Scenario(horizon=200.0), base_rate=6.0,
                 arrivals=arrivals, controller=ctl, seed=0)
    decisions = {rec.sids[0]: rec.time for rec in ctl.records
                 if rec.action == "add"}
    assert decisions
    # pending servers that never became ready are still pending — fine; the
    # ones that joined did so >= lag after their decision (the join shows up
    # as the 'auto-add' sid in the telemetry-driven log)
    ctl2 = make_controller(AlwaysAdd(), interval=5.0, cooldown=30.0,
                           warmup_lag=12.0)
    res = run_scenario([mk("b0")], SPEC, Scenario(horizon=200.0),
                       base_rate=6.0, arrivals=arrivals, controller=ctl2,
                       seed=0)
    join_times = {}
    for e in res.log:
        if e.kind.startswith("auto-add"):
            for sid in e.sid.split(","):
                if sid:
                    join_times.setdefault(sid, e.time)
    decisions2 = {rec.sids[0]: rec.time for rec in ctl2.records
                  if rec.action == "add"}
    joined = set(join_times) & set(decisions2)
    assert joined
    for sid in joined:
        assert join_times[sid] >= decisions2[sid] + 12.0 - 1e-9


def test_min_max_bounds_enforced():
    ctl = make_controller(AlwaysAdd(), interval=5.0, cooldown=0.0,
                          max_servers=3)
    arrivals = diurnal_poisson(6.0, 200.0, amplitude=0.5, seed=1)
    run_scenario([mk("b0")], SPEC, Scenario(horizon=200.0), base_rate=6.0,
                 arrivals=arrivals, controller=ctl, seed=0)
    assert ctl.peak_servers <= 3


def test_cost_accounting_is_exact_integral():
    """server_seconds equals the hand-computed piecewise-constant integral
    of the provisioned-server count over the billed span."""
    ctl = make_controller(PredictivePolicy(TEMPLATE, lead=30.0, margin=1.2),
                          interval=5.0, cooldown=20.0, warmup_lag=10.0)
    # reconstruct the integral from the billing calls the controller makes
    segments = []
    orig_bill = ctl.bill

    def spy_bill(now, n):
        segments.append((now, n))
        orig_bill(now, n)

    ctl.bill = spy_bill
    arrivals = diurnal_poisson(8.0, 400.0, amplitude=0.85, seed=3)
    run_scenario([mk("b0")], SPEC, Scenario(horizon=400.0), base_rate=8.0,
                 arrivals=arrivals, controller=ctl, seed=0)
    # integral from the spy's own records (count in force from each point
    # until the next)
    expect = 0.0
    for (t0, n0), (t1, _) in zip(segments[:-1], segments[1:]):
        expect += n0 * max(0.0, t1 - t0)
    # the final finalize() call is in the segment list too (same timestamp)
    assert ctl.server_seconds == pytest.approx(expect, rel=1e-9)
    assert ctl.server_seconds > 400.0        # at least one server always up


def test_predictive_provisions_ahead_of_ramp():
    """On a scripted ramp the predictive policy orders capacity before the
    reactive target-utilization policy does."""
    ramp = Scenario(horizon=300.0).burst(60.0, 240.0, 6.0)
    arrivals = ramp.generate_arrivals(2.0, seed=5)

    first_add = {}
    for name, pol in (("pred", PredictivePolicy(TEMPLATE, lead=30.0,
                                                margin=1.2)),
                      ("util", TargetUtilizationPolicy())):
        ctl = make_controller(pol, interval=5.0, cooldown=15.0,
                              warmup_lag=10.0)
        run_scenario([mk("b0"), mk("b1")], SPEC, ramp, base_rate=2.0,
                     arrivals=arrivals, controller=ctl, seed=0)
        adds = [rec.time for rec in ctl.records if rec.action == "add"]
        first_add[name] = min(adds) if adds else math.inf
    assert first_add["pred"] < math.inf
    assert first_add["pred"] <= first_add["util"]


def test_all_policies_close_the_loop_in_simulation():
    arrivals = diurnal_poisson(8.0, 300.0, amplitude=0.85, seed=3)
    for pol in (TargetUtilizationPolicy(), QueueGradientPolicy(),
                PredictivePolicy(TEMPLATE, lead=30.0, margin=1.2)):
        ctl = make_controller(pol)
        res = run_scenario([mk("b0")], SPEC, Scenario(horizon=300.0),
                           base_rate=8.0, arrivals=arrivals,
                           controller=ctl, seed=0)
        assert res.completed_all, pol.name
        assert res.result.n_completed == res.n_jobs
        assert ctl.peak_servers >= 2, pol.name   # the loop actually scaled


def test_predictive_dominates_static_on_diurnal():
    """The benchmark's headline claim, in miniature: fewer server-seconds at
    equal-or-better p99 than the peak-provisioned static cluster."""
    arrivals = diurnal_poisson(8.0, 300.0, amplitude=0.85, seed=3)
    scenario = Scenario(horizon=300.0)
    peak = 8.0 * 1.85
    n_static = servers_needed([], TEMPLATE, SPEC, peak, 0.7, max_extra=40)
    static = [mk(f"st{i}") for i in range(n_static)]
    rs = run_scenario(static, SPEC, scenario, base_rate=8.0,
                      arrivals=arrivals, seed=0)
    srep = static_baseline_cost(n_static, rs.result.sim_time,
                                rs.result.response_times, 3.0)
    ctl = make_controller(PredictivePolicy(TEMPLATE, lead=30.0, margin=1.2))
    ra = run_scenario([mk("b0")], SPEC, scenario, base_rate=8.0,
                      arrivals=arrivals, controller=ctl, seed=0)
    arep = ctl.report(ra.result.response_times, 0)
    assert ra.p99() <= rs.p99() + 1e-9
    assert arep.server_seconds < srep.server_seconds


# ---------------------------------------------------------------------------
# Live (mock-model) orchestrator plane
# ---------------------------------------------------------------------------

def _timed_requests(horizon=120.0, base=2.0, seed=0):
    rng = np.random.default_rng(seed)
    times = []
    for (a, b, rate) in diurnal_phases(base, horizon, amplitude=0.8,
                                       n_segments=12):
        n = rng.poisson(rate * (b - a) * 0.6)
        times.extend(np.sort(rng.uniform(a, b, n)).tolist())
    times.sort()
    return [(t, Request(rid=i, prompt=np.ones(4, np.int32),
                        max_new_tokens=5, arrival_time=t))
            for i, t in enumerate(times)]


def test_orchestrator_warming_server_gets_no_dispatches():
    orch = mock_orchestrator([mk("b0"), mk("b1")], SPEC, arrival_rate=1.0)
    orch.add_server(mk("warm1"), now=0.0, warmup_until=5.0)
    assert "warm1" in orch.servers and "warm1" in orch.warming
    reqs = [Request(rid=i, prompt=np.ones(4, np.int32), max_new_tokens=3)
            for i in range(8)]
    for t in (1.0, 2.0, 3.0, 4.0):
        orch.submit(reqs[int(t) - 1], t)
        orch.step(t)
        chain_servers = {s for e in orch.engines for s in e.chain.servers}
        assert "warm1" not in chain_servers, f"dispatched during warm-up at {t}"
    orch.step(5.0)                            # deadline passes -> joins
    assert "warm1" not in orch.warming
    chain_servers = {s for e in orch.engines for s in e.chain.servers}
    assert "warm1" in chain_servers


def test_orchestrator_retire_drains_without_request_loss():
    orch = mock_orchestrator([mk("b0"), mk("b1"), mk("b2")], SPEC,
                             arrival_rate=1.0)
    reqs = [Request(rid=i, prompt=np.ones(4, np.int32), max_new_tokens=6)
            for i in range(6)]
    for r in reqs:
        orch.submit(r, 0.0)
    orch.step(1.0)
    victim = orch.engines[0].chain.servers[0]
    orch.retire_servers([victim], 2.0)
    assert victim not in orch.servers
    orch.drain()
    assert all(r.state == State.DONE for r in reqs)
    assert not orch.failed
    # retired requests completed without a retry (graceful, not a crash)
    assert all(r.retries == 0 for r in reqs)


def test_draining_engine_dies_with_its_hardware():
    """A gracefully-retiring chain loses its in-flight work if a server it
    traverses actually fails mid-drain — drained work is not immortal."""
    small = [Server(s, 12.0, 0.05, 0.08) for s in "abcd"]
    orch = mock_orchestrator(small, SPEC, arrival_rate=1.0)
    reqs = [Request(rid=i, prompt=np.ones(4, np.int32), max_new_tokens=50)
            for i in range(8)]
    for r in reqs:
        orch.submit(r, 0.0)
    orch.step(1.0)
    multi = next(e for e in orch.engines
                 if len(e.chain.servers) > 1 and e.requests)
    s_retire, s_fail = multi.chain.servers[0], multi.chain.servers[1]
    orch.retire_servers([s_retire], 2.0)
    assert orch.draining
    orch.fail_servers([s_fail], 3.0)
    assert not any(s_fail in e.chain.servers for e in orch.draining)
    orch.drain()
    assert all(r.state == State.DONE for r in reqs)
    assert any(r.retries > 0 for r in reqs)


def test_fail_group_on_orchestrator():
    orch = mock_orchestrator([mk(f"b{i}") for i in range(4)], SPEC,
                             arrival_rate=1.0)
    reqs = [Request(rid=i, prompt=np.ones(4, np.int32), max_new_tokens=6)
            for i in range(6)]
    for r in reqs:
        orch.submit(r, 0.0)
    orch.step(1.0)
    ev = ScenarioEvent(2.0, "fail_group", sids=("b0", "b1"))
    out = orch.apply_scenario_event(ev, 2.0)
    assert out["kind"] == "fail_group"
    assert "b0" not in orch.servers and "b1" not in orch.servers
    orch.drain()
    assert all(r.state == State.DONE for r in reqs)


def test_controller_closes_loop_on_orchestrator():
    for pol in (TargetUtilizationPolicy(), QueueGradientPolicy(),
                PredictivePolicy(TEMPLATE, lead=20.0, margin=1.2)):
        orch = mock_orchestrator([mk("b0")], SPEC, arrival_rate=1.0)
        ctl = AutoscaleController(
            pol, TEMPLATE,
            ControllerConfig(interval=5.0, cooldown=10.0, warmup_lag=8.0,
                             min_servers=1, max_servers=12,
                             slo_response_time=60.0),
            telemetry=Telemetry(TelemetryConfig(window=20.0)))
        ctl.bind_orchestrator(orch)
        reqs = _timed_requests()
        summary = api.drive_orchestrator(orch, Scenario(horizon=120.0),
                                         reqs, dt=0.5)
        assert summary["finished"] == len(reqs), pol.name
        assert summary["failed"] == 0, pol.name
        assert ctl.server_seconds > 0


# ---------------------------------------------------------------------------
# Scenario-engine satellites: fail_group + token-based service times
# ---------------------------------------------------------------------------

def test_fail_group_loses_no_requests():
    """A correlated (rack) failure mid-run: recomposition still completes
    every request, and the one event removes the whole set."""
    import random

    rng = random.Random(1234)
    servers = [Server(f"s{i}", rng.uniform(15, 40), rng.uniform(0.02, 0.2),
                      rng.uniform(0.02, 0.2)) for i in range(8)]
    spec = ServiceSpec(num_blocks=10, block_size_gb=1.32,
                       cache_size_gb=0.11)
    sc = Scenario(horizon=120.0).fail_group(40.0, ["s1", "s3", "s5"])
    res = run_scenario(servers, spec, sc, base_rate=5.0, seed=0)
    assert res.completed_all
    assert res.result.n_completed == res.n_jobs
    assert res.reconfigurations == 1          # one event, one recompose
    entry = res.log[0]
    assert entry.kind == "fail_group"
    assert set(entry.sid.split(",")) == {"s1", "s3", "s5"}
    assert np.isfinite(res.result.response_times).all()


def test_fail_group_event_validation():
    with pytest.raises(ValueError):
        ScenarioEvent(1.0, "fail_group")      # needs sids


def test_token_service_mode_uses_trace_tokens():
    """Token-based service times: the per-job service demand is exactly the
    token blend, and the run completes on the real azure-like trace."""
    # one fat server -> a single chain, so every job sees the same rate and
    # the sorted service times must be proportional to the sorted works
    servers = [Server("s0", 40.0, 0.02, 0.02)]
    arr = azure_like_trace_np(1500, seed=1)
    horizon = float(arr[0][-1]) + 1.0
    res = run_scenario(servers, SPEC, Scenario(horizon=horizon),
                       base_rate=2.57, arrivals=arr,
                       service_model="tokens", seed=0)
    assert res.completed_all
    works = token_work(arr[2], arr[3])
    assert res.n_jobs == len(works)
    ratio = np.sort(res.result.service_times) / np.sort(works)
    assert ratio.std() / ratio.mean() < 1e-9   # single mu: exact proportion
    # mean ~1 normalization preserves the chain rates' calibration
    assert 0.7 < works.mean() < 1.3
    # heavier tokens really mean more work
    assert token_work([4000], [60])[0] > token_work([500], [10])[0]


def test_token_mode_requires_token_arrays():
    with pytest.raises(ValueError):
        run_scenario([mk("a"), mk("b")], SPEC, Scenario(horizon=10.0),
                     base_rate=1.0, service_model="tokens")


# ---------------------------------------------------------------------------
# Workload additions
# ---------------------------------------------------------------------------

def test_diurnal_phases_shape():
    phases = diurnal_phases(10.0, 600.0, amplitude=0.8, n_segments=24)
    rates = [r for _, _, r in phases]
    assert len(phases) == 24
    assert min(rates) < 3.0 < 17.0 < max(rates)
    assert phases[0][0] == 0.0 and phases[-1][1] == 600.0
    # starts at the trough by default
    assert rates[0] < rates[len(rates) // 2]


def test_diurnal_poisson_tracks_profile():
    times, works = diurnal_poisson(10.0, 600.0, amplitude=0.8, seed=0)
    third = 600.0 / 3
    early = np.sum(times < third)
    mid = np.sum((times >= third) & (times < 2 * third))
    assert mid > 2 * early                    # peak is busier than trough
    assert len(times) == len(works)


def test_trace_replay_phases_recovers_rate():
    times, _ = diurnal_poisson(10.0, 300.0, amplitude=0.6, seed=2)
    phases = trace_replay_phases(times, bin_width=30.0)
    total = sum((b - a) * r for a, b, r in phases)
    assert total == pytest.approx(len(times), rel=0.05)
    assert max(r for _, _, r in phases) > 2 * min(r for _, _, r in phases)
