"""Baselines (PETALS / BPRR / JFFC-only) and the Fig. 8 / Table 1 ordering."""
import random

import pytest

from repro.core import POLICIES, ServiceSpec, Server, compose, simulate
from repro.core.baselines import (
    BPRRRouter,
    PetalsRouter,
    bprr_placement,
    jffc_only_allocation,
    petals_placement,
    simulate_dynamic,
)
from repro.core.simulator import poisson_arrivals
from repro.core.load_balance import JFFC


def _cluster(seed=0, n=12, frac_hi=0.25):
    rng = random.Random(seed)
    servers = []
    for i in range(n):
        hi = rng.random() < frac_hi
        servers.append(
            Server(
                f"s{i}",
                40.0 if hi else 20.0,
                rng.uniform(0.05, 0.25),
                0.109 if hi else 0.175,
            )
        )
    spec = ServiceSpec(num_blocks=24, block_size_gb=1.32, cache_size_gb=0.11)
    return servers, spec


def test_petals_placement_covers_all_blocks():
    servers, spec = _cluster()
    pl = petals_placement(servers, spec, seed=1)
    cover = [0] * (spec.num_blocks + 1)
    for sid, (a, m) in pl.assignment.items():
        for b in range(a, a + m):
            cover[b] += 1
    assert all(c >= 1 for c in cover[1:]), "every block must be hosted somewhere"


def test_dynamic_routers_complete_jobs():
    servers, spec = _cluster(seed=2)
    lam = 0.2
    arrivals = poisson_arrivals(lam, 3000, random.Random(5))
    for Router, Pl in (
        (PetalsRouter, petals_placement(servers, spec, seed=3)),
        (BPRRRouter, bprr_placement(servers, spec, lam, 0.7)),
    ):
        res = simulate_dynamic(Router(servers, Pl, seed=4), arrivals)
        assert res.n_completed == 3000 - 300
        assert res.mean_response > 0


def test_slot_accounting_never_negative():
    servers, spec = _cluster(seed=6)
    pl = petals_placement(servers, spec, seed=6)
    router = PetalsRouter(servers, pl, seed=6)
    arrivals = poisson_arrivals(0.3, 2000, random.Random(6))
    simulate_dynamic(router, arrivals)
    # all jobs completed -> slots restored to initial
    from repro.core import initial_slots

    assert router.slots == initial_slots(servers, spec, pl)
    assert all(v == 0 for v in router.active.values())


def test_overall_ordering_proposed_beats_baselines():
    """Fig. 8 / Table 1: Proposed (GBP-CR + GCA + JFFC) < BPRR < PETALS in
    mean response time, on a moderately loaded heterogeneous cluster."""
    servers, spec = _cluster(seed=9, n=14, frac_hi=0.3)
    lam = 0.35
    arrivals = poisson_arrivals(lam, 12_000, random.Random(11))

    _, placement, alloc = compose(servers, spec, lam, rho_bar=0.7)
    pairs = alloc.sorted_by_rate()
    pol = JFFC([c.rate for c, _ in pairs], [cap for _, cap in pairs])
    proposed = simulate(pol, arrivals).mean_response

    petals = simulate_dynamic(
        PetalsRouter(servers, petals_placement(servers, spec, seed=12), seed=12),
        arrivals,
    ).mean_response
    bprr = simulate_dynamic(
        BPRRRouter(servers, bprr_placement(servers, spec, lam, 0.7), seed=13),
        arrivals,
    ).mean_response

    assert proposed < bprr * 1.02, f"proposed={proposed:.2f} bprr={bprr:.2f}"
    assert proposed < petals, f"proposed={proposed:.2f} petals={petals:.2f}"


def test_jffc_only_when_model_fits():
    servers, spec = _cluster(seed=3)
    out = jffc_only_allocation(servers, spec)
    if out is None:
        pytest.skip("model does not fit in any single server for this draw")
    pl, alloc = out
    assert all(len(ch.servers) == 1 for ch in alloc.chains)


def test_jffc_only_none_when_too_big():
    servers = [Server("a", 10.0, 0.1, 0.1)]
    spec = ServiceSpec(num_blocks=64, block_size_gb=1.0, cache_size_gb=0.1)
    assert jffc_only_allocation(servers, spec) is None
