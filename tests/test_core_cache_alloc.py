"""GCA (Alg. 2): memory conservation, Fig. 2 example, ILP comparison."""
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    ChainGraph,
    Server,
    ServiceSpec,
    gbp_cr,
    gca,
    initial_slots,
    optimal_ilp,
    rate_lower_bound,
    reserved_allocation,
)
from repro.core.placement import Placement


def fig2_instance():
    """The Fig. 2 example: 5 servers, L=3, s_m=1, s_c=0.1,
    M = 3 for j2 else 2; tau_c = 2 for j2 else 1; tau_p^{j_l} = l*eps."""
    eps = 1e-3
    servers = [
        Server("j1", 2.0, 1.0, 1 * eps),
        Server("j2", 3.0, 2.0, 2 * eps),
        Server("j3", 2.0, 1.0, 3 * eps),
        Server("j4", 2.0, 1.0, 4 * eps),
        Server("j5", 2.0, 1.0, 5 * eps),
    ]
    spec = ServiceSpec(num_blocks=3, block_size_gb=1.0, cache_size_gb=0.1)
    return servers, spec, eps


def test_fig2_gbp_cr_chains():
    servers, spec, eps = fig2_instance()
    pl = gbp_cr(servers, spec, c=1, arrival_rate=100.0, rho_bar=0.7, use_all_servers=True)
    # amortized times: j1: (1+eps)/1, j2: (2+2eps)/2 ~ 1+eps... j2 holds 2 blocks.
    # The paper's Fig. 2a: chains {j1->j2} and {j3->j4->j5}.
    assert [sorted(c) for c in map(sorted, pl.chains)] == [["j1", "j2"], ["j3", "j4", "j5"]]


def test_fig2_gca_finds_three_chains():
    servers, spec, eps = fig2_instance()
    pl = gbp_cr(servers, spec, c=1, arrival_rate=100.0, rho_bar=0.7, use_all_servers=True)
    alloc = gca(servers, pl)
    keys = {tuple(ch.servers) for ch in alloc.chains}
    assert keys == {("j1", "j2"), ("j1", "j4", "j5"), ("j3", "j4", "j5")}
    # Each with capacity 5 (paper's Eq. 16 narrative).
    assert sorted(alloc.capacities) == [5, 5, 5]
    # Total rate matches Eq. (16): 5/(3+5e) + 5/(3+10e) + 5/(3+12e)
    expect = 5 / (3 + 5 * eps) + 5 / (3 + 10 * eps) + 5 / (3 + 12 * eps)
    assert alloc.total_rate == pytest.approx(expect, rel=1e-9)


def _random_cluster(seed, n=8, L=10):
    rng = random.Random(seed)
    servers = [
        Server(
            f"s{i}",
            rng.uniform(8, 40),
            rng.uniform(0.01, 0.5),
            rng.uniform(0.01, 0.3),
        )
        for i in range(n)
    ]
    spec = ServiceSpec(num_blocks=L, block_size_gb=1.32, cache_size_gb=0.11)
    return servers, spec


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), c=st.integers(1, 5))
def test_gca_memory_conservation(seed, c):
    """Property: sum over chains of slot usage per server == initial - residual,
    and residual >= 0 (cache slots never oversubscribed)."""
    servers, spec = _random_cluster(seed)
    pl = gbp_cr(servers, spec, c, 0.01, 0.7, use_all_servers=True)
    if not pl.assignment:
        return
    alloc = gca(servers, pl)
    used = {sid: 0 for sid in alloc.residual_slots}
    for ch, cap in zip(alloc.chains, alloc.capacities):
        assert cap >= 1
        for sid, m_ij in ch.hops():
            used[sid] += m_ij * cap
    init = initial_slots(servers, spec, pl)
    for sid, r in alloc.residual_slots.items():
        assert r >= 0
        assert used.get(sid, 0) + r == init[sid]


def _assert_gca_conservation(servers, spec, pl, alloc):
    """Granted capacities never exceed the residual slots they consumed, and
    residuals stay non-negative: used + residual == initial, per server."""
    used = {sid: 0 for sid in alloc.residual_slots}
    for ch, cap in zip(alloc.chains, alloc.capacities):
        assert cap >= 1
        for sid, m_ij in ch.hops():
            used[sid] = used.get(sid, 0) + m_ij * cap
    init = initial_slots(servers, spec, pl)
    for sid, r in alloc.residual_slots.items():
        assert r >= 0, f"{sid} oversubscribed"
        assert used.get(sid, 0) + r == init[sid]


def test_gca_conservation_deterministic():
    """Seeded sweep of GCA memory conservation (runs without hypothesis)."""
    for seed in range(30):
        rng = random.Random(seed * 7 + 1)
        servers = [
            Server(f"s{i}", rng.uniform(8, 40), rng.uniform(0.01, 0.5),
                   rng.uniform(0.01, 0.3))
            for i in range(rng.randint(3, 10))
        ]
        spec = ServiceSpec(num_blocks=rng.randint(3, 12),
                           block_size_gb=1.32, cache_size_gb=0.11)
        c = rng.randint(1, 5)
        pl = gbp_cr(servers, spec, c, 0.01, 0.7, use_all_servers=True)
        if not pl.assignment:
            continue
        alloc = gca(servers, pl)
        _assert_gca_conservation(servers, spec, pl, alloc)
        # capacities were bounded by the residuals available when granted
        assert all(cap >= 1 for cap in alloc.capacities)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_gca_beats_reserved_allocation(seed):
    """GCA's total service rate >= the c*K(c) reserved allocation (it can only
    add capacity on top of the disjoint chains)."""
    servers, spec = _random_cluster(seed)
    pl = gbp_cr(servers, spec, 2, 0.01, 0.7, use_all_servers=True)
    if not pl.chains:
        return
    alloc = gca(servers, pl)
    reserved = reserved_allocation(servers, pl)
    assert alloc.total_rate >= reserved.total_rate - 1e-9


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 3_000))
def test_gca_vs_conditional_ilp(seed):
    """Fig. 4: the ILP over GCA's chain set needs <= capacity than a naive
    greedy fill to hit the required rate; GCA's chains can realize the ILP's
    requirement; and the analytic lower bound holds."""
    servers, spec = _random_cluster(seed, n=6, L=8)
    pl = gbp_cr(servers, spec, 2, 0.01, 0.7, use_all_servers=True)
    if not pl.chains:
        return
    alloc = gca(servers, pl)
    if not alloc.chains:
        return
    required = 0.5 * alloc.total_rate
    caps = optimal_ilp(servers, pl, alloc.chains, required)
    assert caps is not None, "ILP must be feasible at 50% of GCA's rate"
    total_ilp = sum(caps)
    lb = rate_lower_bound(alloc.chains, required)
    assert total_ilp >= lb
    # The ILP respects the same memory budget:
    init = initial_slots(servers, spec, pl)
    used = {}
    for ch, cap in zip(alloc.chains, caps):
        for sid, m_ij in ch.hops():
            used[sid] = used.get(sid, 0) + m_ij * cap
    for sid, u in used.items():
        assert u <= init[sid]
    # And achieves the rate:
    got = sum(c * ch.rate for c, ch in zip(caps, alloc.chains))
    assert got >= required - 1e-9


def test_chain_graph_edges_follow_definition():
    servers, spec, _ = fig2_instance()
    pl = gbp_cr(servers, spec, c=1, arrival_rate=100.0, rho_bar=0.7, use_all_servers=True)
    g = ChainGraph(servers, pl)
    for (i, j), m_ij in g.edges.items():
        if i == "__j0__":
            fi = 1
        else:
            a_i, m_i = pl.assignment[i]
            fi = a_i + m_i
        if j == "__jT__":
            a_j, m_j = spec.num_blocks + 1, 1
        else:
            a_j, m_j = pl.assignment[j]
        assert a_j <= fi <= a_j + m_j - 1
        assert m_ij == a_j + m_j - fi >= 1
