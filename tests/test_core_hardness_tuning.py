"""Hardness reduction sanity (Thm 3.1 / Lemma 3.3) + tuning (Eq. 14, §3.2.3)."""
import itertools
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Server, ServiceSpec, compose, gca, tune_bound, tune_surrogate
from repro.core.hardness import (
    CacheAllocInstance,
    MKPInstance,
    mkp_to_cache_alloc,
    partition_brute_force,
    partition_to_placement,
    two_chain_feasible,
)
from repro.core.servers import max_blocks, service_time


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 4),
    d=st.integers(1, 3),
    seed=st.integers(0, 9999),
)
def test_thm31_reduction_preserves_optimum(k, d, seed):
    """The MKP optimum equals the max total rate of the constructed
    cache-allocation instance (Theorem 3.1's equivalence)."""
    rng = random.Random(seed)
    inst = MKPInstance(
        values=[rng.randint(1, 9) for _ in range(k)],
        sizes=[[rng.randint(0, 5) for _ in range(k)] for _ in range(d)],
        capacities=[rng.randint(1, 8) for _ in range(d)],
    )
    cache_inst = mkp_to_cache_alloc(inst)
    assert cache_inst.brute_force_max_rate() == pytest.approx(inst.brute_force())


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 9999), n=st.integers(2, 6))
def test_lemma33_reduction(seed, n):
    """Partition feasible <=> two disjoint chains achieve scaled rate 2/L."""
    rng = random.Random(seed)
    xs = [rng.randint(1, 12) for _ in range(n)]
    if sum(xs) % 2:
        xs[0] += 1
    servers, spec, req = partition_to_placement(xs)
    # construction sanity: m_j(1) == x_j and t_j(1) == x_j
    for srv, x in zip(servers, xs):
        assert max_blocks(srv, spec, 1) == min(x, spec.num_blocks)
        if x <= spec.num_blocks:
            assert service_time(srv, spec, 1) == pytest.approx(x)
    assert partition_brute_force(xs) == two_chain_feasible(xs)


def _cluster(seed=0, n=10):
    rng = random.Random(seed)
    servers = []
    for i in range(n):
        hi = rng.random() < 0.3
        servers.append(
            Server(
                f"s{i}",
                40.0 if hi else 20.0,
                rng.uniform(0.02, 0.2),
                0.109 if hi else 0.175,
            )
        )
    spec = ServiceSpec(num_blocks=24, block_size_gb=1.32, cache_size_gb=0.11)
    return servers, spec


def test_tune_surrogate_finds_feasible_c():
    servers, spec = _cluster()
    res = tune_surrogate(servers, spec, lam=0.2, rho_bar=0.7)
    assert res.c_star >= 1
    assert all(obj > 0 for _, obj in res.per_c)
    # objective is c * K(c), integral
    cs = dict(res.per_c)
    assert cs[res.c_star] == res.objective


def test_tune_bound_prefers_more_cache_at_high_load():
    """Fig. 7: optimal c* grows with the arrival rate."""
    servers, spec = _cluster(seed=3, n=12)
    low = tune_bound(servers, spec, lam=0.05, rho_bar=0.7, which="lower")
    high = tune_bound(servers, spec, lam=1.2, rho_bar=0.7, which="lower")
    assert high.c_star >= low.c_star


def test_compose_end_to_end():
    servers, spec = _cluster(seed=5)
    c_star, placement, alloc = compose(servers, spec, lam=0.2, rho_bar=0.7)
    assert alloc.total_rate >= 0.2 / 0.7 - 1e-9
    # chains cover all blocks
    for ch in alloc.chains:
        assert sum(ch.blocks) == spec.num_blocks
    # composed system is stable at lambda
    from repro.core import is_stable

    assert is_stable(alloc.job_servers(), 0.2)


def test_infeasible_demand_raises():
    servers, spec = _cluster(seed=1, n=3)
    with pytest.raises(ValueError):
        tune_surrogate(servers, spec, lam=1e9, rho_bar=0.7)
