"""JFFC (Alg. 3) semantics + policy comparison (Fig. 5a ordering)."""
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import POLICIES, simulate, simulate_policy_name, total_rate
from repro.core.load_balance import JFFC
from repro.core.simulator import Job, poisson_arrivals


def test_jffc_prefers_fastest_free_chain():
    pol = JFFC([3.0, 2.0, 1.0], [1, 1, 1])
    j = Job(0, 0.0, 1.0)
    assert pol.on_arrival(j) == 0
    pol.running[0] = 1
    assert pol.on_arrival(j) == 1
    pol.running[1] = 1
    pol.running[2] = 1
    assert pol.on_arrival(j) is None          # queued
    assert pol.queue_len() == 1
    # Departure on chain 2 pulls the queued job onto chain 2 (Alg. 3 line 7).
    nxt = pol.on_departure(2)
    assert nxt is not None and nxt.assigned_chain == 2


def test_jffc_capacity_respected_in_simulation():
    js = [(2.0, 2), (1.0, 3)]
    lam = 0.8 * total_rate(js)
    rates = [m for m, _ in js]
    caps = [c for _, c in js]
    pol = JFFC(rates, caps)
    orig_arrival = pol.on_arrival

    max_seen = [0, 0]

    def checked(job):
        k = orig_arrival(job)
        if k is not None:
            max_seen[k] = max(max_seen[k], pol.running[k] + 1)
            assert pol.running[k] < caps[k]
        return k

    pol.on_arrival = checked
    simulate(pol, poisson_arrivals(lam, 20_000, random.Random(7)))
    assert max_seen[0] <= caps[0] and max_seen[1] <= caps[1]


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 50))
def test_policy_ordering_fig5(seed):
    """JFFC should (statistically) beat JSQ and JIQ on heterogeneous chains —
    the paper's Fig. 5a finding.  We assert non-trivial wins with slack to
    absorb Monte-Carlo noise."""
    rng = random.Random(seed)
    js = sorted(
        [(rng.uniform(0.5, 3.0), rng.randint(1, 3)) for _ in range(4)],
        key=lambda p: -p[0],
    )
    lam = 0.7 * total_rate(js)
    res = {
        name: simulate_policy_name(name, js, lam, 25_000, seed=seed).mean_response
        for name in ("jffc", "jsq", "jiq", "sa-jsq", "sed")
    }
    assert res["jffc"] <= res["jsq"] * 1.05
    assert res["jffc"] <= res["jiq"] * 1.05


def test_work_conservation():
    """No job waits while some chain has free capacity (JFFC property)."""
    js = [(1.5, 2), (1.0, 2)]
    rates = [m for m, _ in js]
    caps = [c for _, c in js]
    pol = JFFC(rates, caps)
    orig = pol.on_arrival

    def checked(job):
        k = orig(job)
        if k is None:
            assert all(pol.running[i] >= caps[i] for i in range(len(caps)))
        return k

    pol.on_arrival = checked
    simulate(pol, poisson_arrivals(0.7 * total_rate(js), 10_000, random.Random(3)))


def test_all_policies_complete_all_jobs():
    js = [(2.0, 1), (1.0, 2)]
    lam = 0.6 * total_rate(js)
    for name in POLICIES:
        res = simulate_policy_name(name, js, lam, 5_000, seed=11)
        assert res.n_completed == 5_000 - int(5_000 * 0.1)
