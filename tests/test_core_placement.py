"""GBP-CR (Alg. 1) behaviour + Theorem 3.4 optimality + Fig. 1 example."""
import math
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    Server,
    ServiceSpec,
    chains_needed_from_servers,
    disjoint_chain_objects,
    gbp_cr,
    max_blocks,
    random_placement,
    service_time,
)


def homogeneous_cluster(n=8, mem=40.0, tau_c=0.05, tau_p=0.1):
    return [Server(f"s{i}", mem, tau_c, tau_p) for i in range(n)]


SPEC = ServiceSpec(num_blocks=20, block_size_gb=1.32, cache_size_gb=0.11)


def test_max_blocks_eq8():
    srv = Server("a", 40.0, 0.0, 0.1)
    # m_j(c) = min(floor(M / (s_m + s_c c)), L)
    assert max_blocks(srv, SPEC, 1) == min(int(40.0 / (1.32 + 0.11)), 20)
    assert max_blocks(srv, SPEC, 7) == min(int(40.0 / (1.32 + 0.77)), 20)
    # large c -> zero blocks
    tiny = Server("b", 1.4, 0.0, 0.1)
    assert max_blocks(tiny, SPEC, 10) == 0


def test_gbp_cr_covers_blocks_in_order():
    servers = homogeneous_cluster()
    pl = gbp_cr(servers, SPEC, c=3, arrival_rate=0.1, rho_bar=0.7, use_all_servers=True)
    assert pl.chains, "expected at least one complete chain"
    for chain in pl.chains:
        assert pl.covered(chain)
    # disjointness
    flat = [s for ch in pl.chains for s in ch]
    assert len(flat) == len(set(flat))


def test_gbp_cr_sorts_fast_servers_first():
    # Fast servers (low amortized time) must land in the first chain.
    servers = [Server(f"f{i}", 40.0, 0.01, 0.01) for i in range(4)] + [
        Server(f"slow{i}", 40.0, 0.5, 0.5) for i in range(4)
    ]
    pl = gbp_cr(servers, SPEC, c=3, arrival_rate=5.0, rho_bar=0.7, use_all_servers=True)
    assert len(pl.chains) >= 2
    assert all(s.startswith("f") for s in pl.chains[0])


def test_gbp_cr_infeasible_flag():
    servers = homogeneous_cluster(n=2)
    pl = gbp_cr(servers, SPEC, c=1, arrival_rate=1e9, rho_bar=0.7)
    assert not pl.feasible


def test_fig1_capacity_tradeoff():
    """Fig. 1: c=1 -> L single-server chains; c=L^2 -> one L-server chain."""
    L = 6
    s_m, s_c = 1.0, 1.0 / L        # s_m = L * s_c
    spec = ServiceSpec(L, s_m, s_c)
    mem = (L + 1) * s_m
    tau_c, tau_p = 0.3, 0.05
    servers = [Server(f"s{i}", mem, tau_c, tau_p) for i in range(L)]

    # c = 1: m_j = min(floor((L+1)/(1 + 1/L)), L) = L -> single-server chains.
    pl1 = gbp_cr(servers, spec, 1, 1e-6, 0.7, use_all_servers=True)
    assert all(len(ch) == 1 for ch in pl1.chains) and len(pl1.chains) == L
    ch1 = disjoint_chain_objects(servers, pl1)
    assert ch1[0].service_time == pytest.approx(tau_c + L * tau_p)

    # c = L^2: m_j = floor((L+1)s_m/(s_m + L s_c... )) -> 1 block each.
    c2 = L * L
    pl2 = gbp_cr(servers, spec, c2, 1e-6, 0.7, use_all_servers=True)
    assert len(pl2.chains) == 1 and len(pl2.chains[0]) == L
    ch2 = disjoint_chain_objects(servers, pl2)
    assert ch2[0].service_time == pytest.approx(L * tau_c + L * tau_p)
    # T(1) < T(2) but capacity-weighted rate favours config 2:
    assert ch1[0].service_time < ch2[0].service_time
    v1 = L / ch1[0].service_time          # L chains of capacity 1
    v2 = c2 / ch2[0].service_time         # 1 chain of capacity L^2
    assert v2 > v1


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(4, 12),
    c=st.integers(1, 8),
    seed=st.integers(0, 10_000),
)
def test_theorem_3_4_homogeneous_optimality(n, c, seed):
    """Under homogeneous memory, GBP-CR's chain count is <= any random
    feasible grouping achieving the same scaled rate (Thm 3.4 checked against
    randomized search as in Fig. 3a)."""
    rng = random.Random(seed)
    servers = [
        Server(f"s{i}", 40.0, rng.uniform(0.01, 0.4), rng.uniform(0.02, 0.3))
        for i in range(n)
    ]
    spec = ServiceSpec(num_blocks=12, block_size_gb=1.32, cache_size_gb=0.11)
    lam = 0.05
    pl = gbp_cr(servers, spec, c, lam, 0.7, use_all_servers=True)
    k_star = chains_needed_from_servers(servers, spec, pl, lam, 0.7)
    if k_star is None:
        return  # infeasible demand for this draw; nothing to compare
    for trial in range(20):
        rp = random_placement(servers, spec, c, random.Random(seed * 31 + trial))
        k_rand = chains_needed_from_servers(servers, spec, rp, lam, 0.7)
        if k_rand is not None:
            assert k_star <= k_rand


def _random_servers(rng, n, mem_lo=10.0, mem_hi=45.0):
    return [
        Server(f"s{i}", rng.uniform(mem_lo, mem_hi), rng.uniform(0.0, 0.4),
               rng.uniform(0.01, 0.3))
        for i in range(n)
    ]


def _assert_placement_invariants(pl, spec):
    """Chains are disjoint, each covers blocks 1..L in order, and
    ``Placement.covered`` agrees with the chain lists."""
    flat = [sid for chain in pl.chains for sid in chain]
    assert len(flat) == len(set(flat)), "chains share a server"
    for chain in pl.chains:
        assert chain, "empty chain"
        assert pl.covered(chain), f"chain {chain} does not cover 1..L"
        # coverage is order-sensitive: a proper suffix misses block 1 unless
        # its head was independently placed at a = 1
        tail = chain[1:]
        if tail and pl.assignment[tail[0]][0] != 1:
            assert not pl.covered(tail)
    assert not pl.covered([])
    # every placed server respects block bounds
    for sid, (a, m) in pl.assignment.items():
        assert 1 <= a and a + m - 1 <= spec.num_blocks


def test_gbp_cr_chains_disjoint_and_cover_deterministic():
    """Seeded sweep of the placement invariants (runs without hypothesis)."""
    for seed in range(40):
        rng = random.Random(seed)
        servers = _random_servers(rng, rng.randint(3, 14))
        spec = ServiceSpec(num_blocks=rng.randint(4, 16),
                           block_size_gb=1.0, cache_size_gb=0.15)
        c = rng.randint(1, 6)
        pl = gbp_cr(servers, spec, c, arrival_rate=0.05, rho_bar=0.7,
                    use_all_servers=True)
        _assert_placement_invariants(pl, spec)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(2, 14),
    L=st.integers(2, 20),
    c=st.integers(1, 6),
    seed=st.integers(0, 100_000),
)
def test_gbp_cr_chains_disjoint_and_cover_property(n, L, c, seed):
    rng = random.Random(seed)
    servers = _random_servers(rng, n)
    spec = ServiceSpec(num_blocks=L, block_size_gb=1.0, cache_size_gb=0.15)
    pl = gbp_cr(servers, spec, c, arrival_rate=0.05, rho_bar=0.7,
                use_all_servers=True)
    _assert_placement_invariants(pl, spec)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 10),
    c=st.integers(1, 6),
    mem=st.floats(5.0, 80.0),
    seed=st.integers(0, 999),
)
def test_placement_memory_invariant(n, c, mem, seed):
    """Property: every placed server respects its memory with c reserved slots
    per block (Eq. 8)."""
    rng = random.Random(seed)
    servers = [
        Server(f"s{i}", mem * rng.uniform(0.5, 1.5), rng.uniform(0, 0.3), rng.uniform(0.01, 0.3))
        for i in range(n)
    ]
    spec = ServiceSpec(num_blocks=10, block_size_gb=1.0, cache_size_gb=0.2)
    pl = gbp_cr(servers, spec, c, 0.01, 0.7, use_all_servers=True)
    by_id = {s.sid: s for s in servers}
    for sid, (a, m) in pl.assignment.items():
        srv = by_id[sid]
        assert 1 <= a and a + m - 1 <= spec.num_blocks
        assert m * (spec.block_size_gb + spec.cache_size_gb * c) <= srv.memory_gb + 1e-9
