"""Theorem 3.7 bounds, exact K=2 CTMC (App. A.3), simulation agreement."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    exact_occupancy_ctmc,
    exact_occupancy_k2,
    is_stable,
    occupancy_lower_bound,
    occupancy_upper_bound,
    response_time_bounds,
    simulate_policy_name,
    total_rate,
)


def test_mm1_special_case():
    """Single chain, capacity 1 -> M/M/1: E[N] = rho/(1-rho); both bounds tight."""
    mu, lam = 2.0, 1.0
    js = [(mu, 1)]
    expect = (lam / mu) / (1 - lam / mu)
    assert occupancy_lower_bound(js, lam) == pytest.approx(expect, rel=1e-9)
    assert occupancy_upper_bound(js, lam) == pytest.approx(expect, rel=1e-9)


def test_mmc_special_case_vs_ctmc():
    """Single chain, capacity c -> M/M/c: bounds coincide and match the
    truncated-CTMC ground truth."""
    js = [(1.0, 4)]
    lam = 2.5
    lo = occupancy_lower_bound(js, lam)
    hi = occupancy_upper_bound(js, lam)
    exact = exact_occupancy_ctmc(js, lam, queue_cap=800)
    assert lo == pytest.approx(hi, rel=1e-9)
    assert lo == pytest.approx(exact, rel=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    mu1=st.floats(1.1, 4.0),
    mu2=st.floats(0.2, 1.0),
    c1=st.integers(1, 3),
    c2=st.integers(1, 3),
    rho=st.floats(0.2, 0.85),
)
def test_k2_exact_within_bounds_and_matches_ctmc(mu1, mu2, c1, c2, rho):
    js = [(mu1, c1), (mu2, c2)]
    lam = rho * total_rate(js)
    exact = exact_occupancy_k2(mu1, c1, mu2, c2, lam)
    ctmc = exact_occupancy_ctmc(js, lam, queue_cap=2000)
    assert exact == pytest.approx(ctmc, rel=2e-2), "A.3 recursion vs numeric CTMC"
    lo = occupancy_lower_bound(js, lam)
    hi = occupancy_upper_bound(js, lam)
    assert lo - 1e-6 <= exact <= hi + 1e-6


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 100),
    rho=st.floats(0.3, 0.7),
)
def test_simulation_within_bounds(seed, rho):
    """JFFC simulation mean occupancy must land within the Thm 3.7 bounds
    (up to Monte-Carlo noise)."""
    import random

    rng = random.Random(seed)
    K = rng.randint(2, 4)
    mus = sorted((rng.uniform(0.3, 3.0) for _ in range(K)), reverse=True)
    js = [(m, rng.randint(1, 4)) for m in mus]
    lam = rho * total_rate(js)
    lo, hi = response_time_bounds(js, lam)
    res = simulate_policy_name("jffc", js, lam, n_jobs=40_000, seed=seed)
    mean_rt = res.mean_response
    assert lo * 0.9 - 0.05 <= mean_rt <= hi * 1.12 + 0.05, (
        f"sim {mean_rt:.3f} outside [{lo:.3f}, {hi:.3f}]"
    )


def test_instability_detection():
    js = [(1.0, 2)]
    assert is_stable(js, 1.9)
    assert not is_stable(js, 2.0)
    assert occupancy_lower_bound(js, 2.5) == math.inf


def test_bounds_monotone_in_lambda():
    js = [(2.0, 2), (1.0, 3)]
    nus = total_rate(js)
    prev_lo = prev_hi = 0.0
    for rho in (0.1, 0.3, 0.5, 0.7, 0.9):
        lo = occupancy_lower_bound(js, rho * nus)
        hi = occupancy_upper_bound(js, rho * nus)
        assert lo >= prev_lo and hi >= prev_hi
        assert lo <= hi + 1e-12
        prev_lo, prev_hi = lo, hi
