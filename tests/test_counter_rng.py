"""The counter-based policy RNG: Threefry-2x32 known-answer vectors,
uniform derivation invariants, the draw adapter, and scheme selection /
validation on the engines."""
import numpy as np
import pytest

from repro.core.engines import RNG_SCHEMES, counter_uniforms, make_engine
from repro.core.engines.counter_rng import CounterDraw, threefry2x32


# Random123 reference vectors (Salmon et al., SC'11 release, kat_vectors)
THREEFRY_KATS = [
    ((0x00000000, 0x00000000), (0x00000000, 0x00000000),
     (0x6B200159, 0x99BA4EFE)),
    ((0xFFFFFFFF, 0xFFFFFFFF), (0xFFFFFFFF, 0xFFFFFFFF),
     (0x1CB996FC, 0xBB002BE7)),
    ((0x13198A2E, 0x03707344), (0x243F6A88, 0x85A308D3),
     (0xC4923A9C, 0x483DF7A0)),
]


@pytest.mark.parametrize("key,ctr,expect", THREEFRY_KATS)
def test_threefry_known_answers(key, ctr, expect):
    x0, x1 = threefry2x32(key[0], key[1], ctr[0], ctr[1])
    assert (int(x0), int(x1)) == expect


def test_threefry_vectorizes_over_counters():
    c0 = np.array([0x00000000, 0xFFFFFFFF, 0x243F6A88], dtype=np.uint32)
    c1 = np.array([0x00000000, 0xFFFFFFFF, 0x85A308D3], dtype=np.uint32)
    # rows 0 and 1 match the all-zero / all-ones KATs under their keys
    x0, _ = threefry2x32(0, 0, c0[:1], c1[:1])
    assert int(x0[0]) == 0x6B200159
    x0, x1 = threefry2x32(0x13198A2E, 0x03707344, c0[2:], c1[2:])
    assert (int(x0[0]), int(x1[0])) == (0xC4923A9C, 0x483DF7A0)


def test_counter_uniforms_range_dtype_and_determinism():
    u = counter_uniforms(12345, np.arange(10_000))
    assert u.dtype == np.float64
    assert np.all((0.0 <= u) & (u < 1.0))
    # exact dyadic rationals m * 2**-32: scaling back is lossless
    m = u * 2.0 ** 32
    assert np.array_equal(m, np.round(m))
    # stateless: any slice equals the full derivation restricted
    assert np.array_equal(u[137:731], counter_uniforms(12345,
                                                       np.arange(137, 731)))
    # key sensitivity: a different seed decorrelates every draw
    assert not np.any(u == counter_uniforms(12346, np.arange(10_000)))


def test_counter_uniforms_wide_seeds_and_jids():
    # seeds wider than 32 bits use both key words
    a = counter_uniforms(1, [0, 1, 2])
    b = counter_uniforms(1 + (1 << 32), [0, 1, 2])
    assert not np.array_equal(a, b)
    # jids wider than 32 bits use both counter words
    wide = counter_uniforms(7, [1 << 33])
    assert wide.shape == (1,) and 0.0 <= wide[0] < 1.0


def test_counter_draw_matches_index_formula():
    d = CounterDraw()
    d.u = 0.999999999
    assert d.randrange(3) == 2
    assert d.choice("abc") == "c"
    d.u = 0.0
    assert d.randrange(3) == 0
    assert d.choice([10, 20]) == 10
    # floor(u * n) never reaches n for u < 1 (dyadic u, small n)
    d.u = (2 ** 32 - 1) * 2.0 ** -32
    for n in (1, 2, 3, 7, 1000):
        assert d.randrange(n) == n - 1


def test_engines_validate_rng_scheme():
    assert RNG_SCHEMES == ("legacy", "counter")
    for engine in ("vector", "batched"):
        for scheme in RNG_SCHEMES:
            e = make_engine(engine, [1.0], [2], policy="jffc",
                            rng_scheme=scheme)
            assert e.rng_scheme == scheme
        with pytest.raises(ValueError, match="rng_scheme"):
            make_engine(engine, [1.0], [2], policy="jffc",
                        rng_scheme="philox")


def test_deterministic_policies_are_scheme_invariant():
    """Policies that never draw produce identical trajectories under both
    schemes; RNG-consuming ones genuinely re-randomize."""
    import random

    from repro.core.simulator import poisson_arrivals, simulate_vectorized

    servers = [(1.0, 2), (0.8, 2), (0.5, 4)]
    arrivals = poisson_arrivals(4.0, 2_000, random.Random(3))
    for policy in ("jffc", "jffs", "sa-jsq", "sed", "priority"):
        a = simulate_vectorized(policy, servers, arrivals, seed=3,
                                rng_scheme="legacy")
        b = simulate_vectorized(policy, servers, arrivals, seed=3,
                                rng_scheme="counter")
        assert np.array_equal(a.response_times, b.response_times), policy
    for policy in ("random", "jsq", "jiq"):
        a = simulate_vectorized(policy, servers, arrivals, seed=3,
                                rng_scheme="legacy")
        b = simulate_vectorized(policy, servers, arrivals, seed=3,
                                rng_scheme="counter")
        assert not np.array_equal(a.response_times, b.response_times), policy
