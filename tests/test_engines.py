"""Cross-backend parity suite: every simulation backend produces
bit-identical ``SimResult``s on fixed seeds.

``engine="vector"`` is the parity anchor (itself pinned to the scalar
oracle by ``test_simulator_parity.py``); ``engine="batched"`` must match it
bit for bit on every policy, through pauses, reconfigurations, and the
compiled JFFC fast path (exercised directly when jax is importable, and by
construction absent when it is not — the suite passes in both the full and
the minimal CI matrices).
"""
import random

import numpy as np
import pytest

from repro.core import (
    RequestClass,
    VECTORIZED_POLICIES,
    classed_poisson_mix,
    engine_names,
    make_engine,
    simulate_vectorized,
)
from repro.core.engines import (
    BatchedEngine,
    ENGINES,
    POLICY_KERNELS,
    RNG_POLICIES,
    VectorEngine,
    jax_available,
    run_grid,
    run_seed_grid,
)
from repro.core.simulator import poisson_arrivals
from repro.core.workload import poisson_exponential_np

SERVERS = [(1.0, 2), (0.8, 2), (0.5, 4)]   # nu = 5.6
RATES = [m for m, _ in SERVERS]
CAPS = [c for _, c in SERVERS]

needs_jax = pytest.mark.skipif(not jax_available(),
                               reason="jax not installed")


def _identical(a, b):
    assert a.n_completed == b.n_completed
    assert np.array_equal(a.response_times, b.response_times)
    assert np.array_equal(a.waiting_times, b.waiting_times)
    assert np.array_equal(a.service_times, b.service_times)
    assert a.sim_time == b.sim_time
    assert a.n_rejected == b.n_rejected
    if a.class_ids is not None or b.class_ids is not None:
        assert np.array_equal(a.class_ids, b.class_ids)


def _pair(policy, seed=3, classes=None, aging=0.0, scan_min=None,
          rng_scheme="legacy"):
    """A (vector, batched) engine pair over the standard chain set."""
    v = make_engine("vector", RATES, CAPS, policy=policy, seed=seed,
                    classes=classes, aging_rate=aging, rng_scheme=rng_scheme)
    b = make_engine("batched", RATES, CAPS, policy=policy, seed=seed,
                    classes=classes, aging_rate=aging, rng_scheme=rng_scheme)
    if scan_min is not None:
        b.scan_min_jobs = scan_min
    return v, b


# ---------------------------------------------------------------------------
# Registry / construction surface
# ---------------------------------------------------------------------------

def test_engine_registry_surface():
    assert engine_names() == ("batched", "vector")
    assert ENGINES["vector"] is VectorEngine
    assert ENGINES["batched"] is BatchedEngine
    assert isinstance(make_engine(None, RATES, CAPS), VectorEngine)
    with pytest.raises(ValueError, match="unknown simulation engine"):
        make_engine("warp", RATES, CAPS)
    assert set(VECTORIZED_POLICIES) == set(POLICY_KERNELS)


@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_engines_reject_unsupported_policy(engine):
    with pytest.raises(ValueError, match="not vectorized"):
        make_engine(engine, RATES, CAPS, policy="round-robin")


# ---------------------------------------------------------------------------
# Bit-identical results, all policies, both completion modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", VECTORIZED_POLICIES)
@pytest.mark.parametrize("lam", [2.0, 5.3])           # light / near-saturated
def test_cross_backend_bit_identical(policy, lam):
    arrivals = poisson_arrivals(lam, 6_000, random.Random(0))
    a = simulate_vectorized(policy, SERVERS, arrivals, seed=3,
                            engine="vector")
    b = simulate_vectorized(policy, SERVERS, arrivals, seed=3,
                            engine="batched")
    _identical(a, b)


def test_cross_backend_priority_multiclass():
    """Priority engine with real classes, aging, and an admission gate:
    the batched backend must shed the same jobs at the same instants."""
    classes = [RequestClass("interactive", "chat", 0, slo_target=2.0),
               RequestClass("batch", "offline", 1, deadline=5.0)]
    t, w, c = classed_poisson_mix([3.9, 1.8], 1_500.0, seed=5)
    for aging in (0.0, 0.02):
        a = simulate_vectorized("priority", SERVERS, (t, w, c), seed=5,
                                classes=classes, aging_rate=aging,
                                engine="vector")
        b = simulate_vectorized("priority", SERVERS, (t, w, c), seed=5,
                                classes=classes, aging_rate=aging,
                                engine="batched")
        _identical(a, b)
        assert np.array_equal(a.rejected_class_ids, b.rejected_class_ids)


def test_cross_backend_segmented_and_reconfigured():
    """Pause / reconfigure mid-run on both backends: restart mode (chain
    retired while saturated) then drain mode (voluntary re-tune), ending
    bit-identical — the scenario engine's full surface."""
    arrivals = poisson_arrivals(4.5, 6_000, random.Random(7))
    horizon = arrivals[-1][0]
    results = []
    for engine in ("vector", "batched"):
        sim = make_engine(engine, RATES, CAPS, policy="jffc", seed=8,
                          keys=["a", "b", "c"])
        sim.add_arrivals(arrivals)
        sim.run_until(0.3 * horizon)
        sim.reconfigure([1.0, 0.5], [2, 4], at_time=0.3 * horizon,
                        keys=["a", "c"], mode="restart")
        sim.run_until(0.6 * horizon)
        sim.reconfigure(RATES, CAPS, at_time=0.6 * horizon,
                        keys=["a", "b", "c"], mode="drain")
        sim.run_to_completion()
        assert sim.queue_len() == 0 and sim.in_flight == 0
        results.append((sim.result(warmup_fraction=0.0), list(sim.comp),
                        sim.restarts, sim.drains, sim.reconfigurations))
    (res_v, comp_v, rst_v, drn_v, rec_v) = results[0]
    (res_b, comp_b, rst_b, drn_b, rec_b) = results[1]
    _identical(res_v, res_b)
    assert comp_v == comp_b
    assert (rst_v, drn_v, rec_v) == (rst_b, drn_b, rec_b)
    assert res_v.n_completed == len(arrivals)


@pytest.mark.parametrize("policy", ["jffs", "priority"])
def test_cross_backend_reconfigure_dedicated_and_priority(policy):
    arrivals = poisson_arrivals(4.5, 4_000, random.Random(13))
    t_half = arrivals[2000][0]
    results = []
    for engine in ("vector", "batched"):
        sim = make_engine(engine, RATES, CAPS, policy=policy, seed=14,
                          keys=["a", "b", "c"])
        sim.add_arrivals(arrivals)
        sim.run_until(t_half)
        sim.reconfigure([1.0, 0.5], [2, 4], at_time=t_half, keys=["a", "c"])
        sim.run_to_completion()
        results.append(sim.result(warmup_fraction=0.0))
    _identical(results[0], results[1])
    assert results[0].n_completed == len(arrivals)


# ---------------------------------------------------------------------------
# The compiled fast path (jax present): forced onto small traces
# ---------------------------------------------------------------------------

@needs_jax
def test_scan_path_engaged_and_identical():
    t, w = poisson_exponential_np(5.0, 3_000, seed=0)
    v, b = _pair("jffc", scan_min=1)
    v.add_arrivals(t, w)
    b.add_arrivals(t, w)
    assert b._scan_eligible()
    v.run_to_completion()
    b.run_to_completion()
    _identical(v.result(), b.result())
    assert v.comp == b.comp                  # completion order, exactly
    assert b.i == b.n and b.in_flight == 0


@needs_jax
def test_scan_path_tie_breaking():
    """Crafted integer-grid trace with identical works: bitwise-equal
    finish times across slots force the (finish, seq) tie-break."""
    n = 600
    t = np.arange(n, dtype=np.float64) * 0.125
    w = np.ones(n, dtype=np.float64)
    servers = [(1.0, 2), (0.5, 2), (0.25, 1)]
    a = simulate_vectorized("jffc", servers, (t, w), seed=1,
                            warmup_fraction=0.0, engine="vector")
    sim = BatchedEngine([m for m, _ in servers], [c for _, c in servers],
                        policy="jffc", seed=2)
    sim.scan_min_jobs = 1
    sim.add_arrivals(t, w)
    sim.run_to_completion()
    _identical(a, sim.result(warmup_fraction=0.0))


@needs_jax
def test_scan_path_resumes_from_paused_state():
    """run_until leaves in-flight work on the heap; the scan must seed its
    slot state from it and still match the interpreter bit for bit."""
    arrivals = poisson_arrivals(4.8, 5_000, random.Random(5))
    horizon = arrivals[-1][0]
    v, b = _pair("jffc", seed=6, scan_min=1)
    v.add_arrivals(arrivals)
    b.add_arrivals(arrivals)
    v.run_until(0.4 * horizon)
    b.run_until(0.4 * horizon)               # finite horizon: interpreter
    assert b.in_flight > 0
    v.run_to_completion()
    b.run_to_completion()                    # resumes via the compiled path
    _identical(v.result(), b.result())
    assert v.comp == b.comp


@needs_jax
def test_run_seed_grid_matches_per_seed_engines():
    """The one-pass vmapped grid == one engine per seed, bit for bit."""
    lam, n, S = 4.8, 2_000, 6
    traces = [poisson_exponential_np(lam, n, seed=s) for s in range(S)]
    grid = run_seed_grid(RATES, CAPS,
                         np.stack([t for t, _ in traces]),
                         np.stack([w for _, w in traces]),
                         warmup_fraction=0.1)
    assert len(grid) == S
    for (t, w), res in zip(traces, grid):
        one = simulate_vectorized("jffc", SERVERS, (t, w), seed=9,
                                  engine="vector")
        _identical(one, res)


# ---------------------------------------------------------------------------
# The compiled event kernel: every dispatch policy, per RNG scheme (PR 6)
# ---------------------------------------------------------------------------

def _scan_schemes(policy):
    """The RNG schemes under which ``policy`` has a compiled path."""
    if policy in RNG_POLICIES:
        return ("counter",)
    return ("legacy", "counter")


@needs_jax
@pytest.mark.parametrize("policy", VECTORIZED_POLICIES)
def test_event_scan_all_policies_engaged_and_identical(policy):
    """Every registered policy (plus priority's class-blind default) takes
    a compiled path and matches the interpreter bit for bit — including
    the emitted completion order — under each scheme it supports."""
    arrivals = poisson_arrivals(4.8, 3_000, random.Random(21))
    t = np.array([a[0] for a in arrivals])
    w = np.array([a[1] for a in arrivals])
    for scheme in _scan_schemes(policy):
        v, b = _pair(policy, seed=21, scan_min=1, rng_scheme=scheme)
        v.add_arrivals(arrivals)
        b.add_arrivals(t, w)
        assert b._scan_eligible(), (policy, scheme)
        v.run_to_completion()
        b.run_to_completion()
        _identical(v.result(), b.result())
        assert v.comp == b.comp
        assert b.i == b.n and b.in_flight == 0


@needs_jax
@pytest.mark.parametrize("policy", sorted(RNG_POLICIES))
def test_rng_policies_fall_back_under_legacy_scheme(policy):
    """The legacy random.Random stream is inherently sequential: RNG
    policies must refuse the compiled path and fall back bit-identically."""
    arrivals = poisson_arrivals(4.8, 3_000, random.Random(23))
    v, b = _pair(policy, seed=23, scan_min=1, rng_scheme="legacy")
    v.add_arrivals(arrivals)
    b.add_arrivals(np.array([a[0] for a in arrivals]),
                   np.array([a[1] for a in arrivals]))
    assert not b._scan_eligible()
    v.run_to_completion()
    b.run_to_completion()
    _identical(v.result(), b.result())


@needs_jax
@pytest.mark.parametrize("policy", sorted(set(VECTORIZED_POLICIES)
                                          - set(("jffc", "priority"))))
def test_event_scan_resumes_from_paused_state(policy):
    """Dedicated-queue policies: pausing leaves in-flight work on the
    heap; the event kernel seeds its slot state from it and the resumed
    stretch still matches the uninterrupted interpreter run."""
    arrivals = poisson_arrivals(4.8, 4_000, random.Random(25))
    horizon = arrivals[-1][0]
    v, b = _pair(policy, seed=25, scan_min=1, rng_scheme="counter")
    v.add_arrivals(arrivals)
    b.add_arrivals(np.array([a[0] for a in arrivals]),
                   np.array([a[1] for a in arrivals]))
    v.run_to_completion()
    for frac in (0.25, 0.6):
        b.run_until(frac * horizon)          # finite horizon: interpreter
    assert b.in_flight > 0 or b.queue_len() > 0 or b.i == b.n
    b.run_to_completion()                    # resumes via the event kernel
    _identical(v.result(), b.result())
    assert v.comp == b.comp


@needs_jax
def test_priority_class_blind_rides_slot_race_kernel():
    """Single default class + no deadline degenerates priority to the
    jffc trajectory — it must engage the compiled slot-race path."""
    t, w = poisson_exponential_np(5.0, 3_000, seed=27)
    v, b = _pair("priority", seed=27, scan_min=1)
    v.add_arrivals(t, w)
    b.add_arrivals(t, w)
    assert b._scan_eligible()
    v.run_to_completion()
    b.run_to_completion()
    _identical(v.result(), b.result())
    # with real classes the degenerate check must refuse the scan
    classes = [RequestClass("i", "chat", 0, slo_target=2.0),
               RequestClass("b", "offline", 1)]
    bb = make_engine("batched", RATES, CAPS, policy="priority", seed=27,
                     classes=classes)
    bb.scan_min_jobs = 1
    tt, ww, cc = classed_poisson_mix([3.6, 1.6], 400.0, seed=27)
    bb.add_arrivals(tt, ww, cc)
    assert not bb._scan_eligible()


@needs_jax
def test_run_grid_matches_per_point_engines():
    """The one-pass policy×seed grid == one engine per point, bit for bit,
    for every policy under the counter scheme."""
    lam, n = 4.8, 1_500
    seeds = [0, 4]
    traces = [poisson_exponential_np(lam, n, seed=s) for s in seeds]
    times = np.stack([t for t, _ in traces])
    works = np.stack([w for _, w in traces])
    for policy in VECTORIZED_POLICIES:
        grid = run_grid(policy, RATES, CAPS, times, works,
                        engine_seeds=[s + 1 for s in seeds],
                        rng_scheme="counter", warmup_fraction=0.1)
        assert len(grid) == len(seeds)
        for s, (t, w), res in zip(seeds, traces, grid):
            one = simulate_vectorized(policy, SERVERS, (t, w), seed=s,
                                      engine="vector", rng_scheme="counter")
            _identical(one, res)


@needs_jax
def test_run_grid_rejects_legacy_rng_policies():
    t, w = poisson_exponential_np(4.0, 64, seed=0)
    with pytest.raises(ValueError, match="rng_scheme='counter'"):
        run_grid("random", RATES, CAPS, t[None], w[None],
                 rng_scheme="legacy")
    with pytest.raises(ValueError, match="engine_seeds"):
        run_grid("jsq", RATES, CAPS, t[None], w[None],
                 rng_scheme="counter")


@needs_jax
def test_run_grid_devices_override_is_bit_stable():
    """devices=1 forces the single-device vmap fallback; results must not
    depend on the sharding choice."""
    traces = [poisson_exponential_np(4.8, 800, seed=s) for s in range(3)]
    times = np.stack([t for t, _ in traces])
    works = np.stack([w for _, w in traces])
    for policy in ("jffc", "sed"):
        a = run_grid(policy, RATES, CAPS, times, works, devices=1)
        b = run_grid(policy, RATES, CAPS, times, works)
        for x, y in zip(a, b):
            _identical(x, y)


# ---------------------------------------------------------------------------
# Ingest validation symmetry (shared-core checks, both backends)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_ingest_validation_symmetric_across_backends(engine):
    """Both ingest paths (list-form and the batched array-native one) run
    the same shared-core checks and raise the same ValueError."""
    t = np.array([0.5, 1.0, 2.0])
    w = np.ones(3)
    sim = make_engine(engine, RATES, CAPS)
    with pytest.raises(ValueError, match="class indices"):
        sim.add_arrivals(t, w, np.array([0, 5, 0]))
    sim = make_engine(engine, RATES, CAPS)
    with pytest.raises(ValueError, match="non-decreasing"):
        sim.add_arrivals(np.array([1.0, 0.5, 2.0]), w)
    sim = make_engine(engine, RATES, CAPS)
    sim.add_arrivals(t, w)
    with pytest.raises(ValueError, match="precedes existing"):
        sim.add_arrivals(np.array([1.5]), np.ones(1))


def test_batched_without_scan_still_batched_engine():
    """Below the scan threshold (or without jax) the batched backend is
    the interpreter in disguise — same results, same telemetry taps."""
    t, w = poisson_exponential_np(5.0, 500, seed=3)
    v, b = _pair("jffc", seed=4)
    assert b.scan_min_jobs > 500             # default threshold: fallback
    v.add_arrivals(t, w)
    b.add_arrivals(t, w)
    v.run_to_completion()
    b.run_to_completion()
    _identical(v.result(), b.result())
    assert v.total_capacity == b.total_capacity
    assert v.completions_since(0) == b.completions_since(0)


# ---------------------------------------------------------------------------
# Property: resume-from-paused-heap is invisible (hypothesis, shimmed)
# ---------------------------------------------------------------------------

from hypothesis import given, settings, strategies as st  # noqa: E402


def _check_paused_resume_invisible(policy, seed, fracs):
    """Body shared by the property test and its deterministic anchor:
    pausing the batched engine at arbitrary horizons (with a no-op
    identity reconfigure at each pause) and resuming through the compiled
    path must reproduce the uninterrupted interpreter run bit for bit."""
    arrivals = poisson_arrivals(4.8, 1_200, random.Random(seed))
    horizon = arrivals[-1][0]
    keys = ["a", "b", "c"]
    v = make_engine("vector", RATES, CAPS, policy=policy, seed=seed,
                    keys=keys, rng_scheme="counter")
    v.add_arrivals(arrivals)
    v.run_to_completion()
    b = make_engine("batched", RATES, CAPS, policy=policy, seed=seed,
                    keys=keys, rng_scheme="counter")
    b.scan_min_jobs = 1
    b.add_arrivals(np.array([a[0] for a in arrivals]),
                   np.array([a[1] for a in arrivals]))
    for frac in sorted(fracs):
        at = frac * horizon
        b.run_until(at)
        requeued = b.reconfigure(RATES, CAPS, at_time=max(at, b.now),
                                 keys=keys)
        assert requeued == 0                 # identity: nothing disturbed
    b.run_to_completion()
    _identical(v.result(), b.result())
    assert v.comp == b.comp


@needs_jax
@settings(max_examples=15, deadline=None)
@given(
    policy=st.sampled_from(sorted(set(VECTORIZED_POLICIES) - {"priority"})),
    seed=st.integers(min_value=0, max_value=60),
    fracs=st.lists(st.floats(min_value=0.02, max_value=0.98),
                   min_size=1, max_size=4),
)
def test_property_paused_resume_invisible(policy, seed, fracs):
    _check_paused_resume_invisible(policy, seed, fracs)


@needs_jax
@pytest.mark.parametrize("policy", ["jffs", "jsq", "sed"])
def test_paused_resume_invisible_anchor(policy):
    """Deterministic anchor for the property above — runs even when
    hypothesis is absent (the conftest shim skips @given tests)."""
    _check_paused_resume_invisible(policy, 31, [0.15, 0.5, 0.85])
