"""Cross-backend parity suite: every simulation backend produces
bit-identical ``SimResult``s on fixed seeds.

``engine="vector"`` is the parity anchor (itself pinned to the scalar
oracle by ``test_simulator_parity.py``); ``engine="batched"`` must match it
bit for bit on every policy, through pauses, reconfigurations, and the
compiled JFFC fast path (exercised directly when jax is importable, and by
construction absent when it is not — the suite passes in both the full and
the minimal CI matrices).
"""
import random

import numpy as np
import pytest

from repro.core import (
    RequestClass,
    VECTORIZED_POLICIES,
    classed_poisson_mix,
    engine_names,
    make_engine,
    simulate_vectorized,
)
from repro.core.engines import (
    BatchedEngine,
    ENGINES,
    POLICY_KERNELS,
    VectorEngine,
    jax_available,
    run_seed_grid,
)
from repro.core.simulator import poisson_arrivals
from repro.core.workload import poisson_exponential_np

SERVERS = [(1.0, 2), (0.8, 2), (0.5, 4)]   # nu = 5.6
RATES = [m for m, _ in SERVERS]
CAPS = [c for _, c in SERVERS]

needs_jax = pytest.mark.skipif(not jax_available(),
                               reason="jax not installed")


def _identical(a, b):
    assert a.n_completed == b.n_completed
    assert np.array_equal(a.response_times, b.response_times)
    assert np.array_equal(a.waiting_times, b.waiting_times)
    assert np.array_equal(a.service_times, b.service_times)
    assert a.sim_time == b.sim_time
    assert a.n_rejected == b.n_rejected
    if a.class_ids is not None or b.class_ids is not None:
        assert np.array_equal(a.class_ids, b.class_ids)


def _pair(policy, seed=3, classes=None, aging=0.0, scan_min=None):
    """A (vector, batched) engine pair over the standard chain set."""
    v = make_engine("vector", RATES, CAPS, policy=policy, seed=seed,
                    classes=classes, aging_rate=aging)
    b = make_engine("batched", RATES, CAPS, policy=policy, seed=seed,
                    classes=classes, aging_rate=aging)
    if scan_min is not None:
        b.scan_min_jobs = scan_min
    return v, b


# ---------------------------------------------------------------------------
# Registry / construction surface
# ---------------------------------------------------------------------------

def test_engine_registry_surface():
    assert engine_names() == ("batched", "vector")
    assert ENGINES["vector"] is VectorEngine
    assert ENGINES["batched"] is BatchedEngine
    assert isinstance(make_engine(None, RATES, CAPS), VectorEngine)
    with pytest.raises(ValueError, match="unknown simulation engine"):
        make_engine("warp", RATES, CAPS)
    assert set(VECTORIZED_POLICIES) == set(POLICY_KERNELS)


@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_engines_reject_unsupported_policy(engine):
    with pytest.raises(ValueError, match="not vectorized"):
        make_engine(engine, RATES, CAPS, policy="round-robin")


# ---------------------------------------------------------------------------
# Bit-identical results, all policies, both completion modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", VECTORIZED_POLICIES)
@pytest.mark.parametrize("lam", [2.0, 5.3])           # light / near-saturated
def test_cross_backend_bit_identical(policy, lam):
    arrivals = poisson_arrivals(lam, 6_000, random.Random(0))
    a = simulate_vectorized(policy, SERVERS, arrivals, seed=3,
                            engine="vector")
    b = simulate_vectorized(policy, SERVERS, arrivals, seed=3,
                            engine="batched")
    _identical(a, b)


def test_cross_backend_priority_multiclass():
    """Priority engine with real classes, aging, and an admission gate:
    the batched backend must shed the same jobs at the same instants."""
    classes = [RequestClass("interactive", "chat", 0, slo_target=2.0),
               RequestClass("batch", "offline", 1, deadline=5.0)]
    t, w, c = classed_poisson_mix([3.9, 1.8], 1_500.0, seed=5)
    for aging in (0.0, 0.02):
        a = simulate_vectorized("priority", SERVERS, (t, w, c), seed=5,
                                classes=classes, aging_rate=aging,
                                engine="vector")
        b = simulate_vectorized("priority", SERVERS, (t, w, c), seed=5,
                                classes=classes, aging_rate=aging,
                                engine="batched")
        _identical(a, b)
        assert np.array_equal(a.rejected_class_ids, b.rejected_class_ids)


def test_cross_backend_segmented_and_reconfigured():
    """Pause / reconfigure mid-run on both backends: restart mode (chain
    retired while saturated) then drain mode (voluntary re-tune), ending
    bit-identical — the scenario engine's full surface."""
    arrivals = poisson_arrivals(4.5, 6_000, random.Random(7))
    horizon = arrivals[-1][0]
    results = []
    for engine in ("vector", "batched"):
        sim = make_engine(engine, RATES, CAPS, policy="jffc", seed=8,
                          keys=["a", "b", "c"])
        sim.add_arrivals(arrivals)
        sim.run_until(0.3 * horizon)
        sim.reconfigure([1.0, 0.5], [2, 4], at_time=0.3 * horizon,
                        keys=["a", "c"], mode="restart")
        sim.run_until(0.6 * horizon)
        sim.reconfigure(RATES, CAPS, at_time=0.6 * horizon,
                        keys=["a", "b", "c"], mode="drain")
        sim.run_to_completion()
        assert sim.queue_len() == 0 and sim.in_flight == 0
        results.append((sim.result(warmup_fraction=0.0), list(sim.comp),
                        sim.restarts, sim.drains, sim.reconfigurations))
    (res_v, comp_v, rst_v, drn_v, rec_v) = results[0]
    (res_b, comp_b, rst_b, drn_b, rec_b) = results[1]
    _identical(res_v, res_b)
    assert comp_v == comp_b
    assert (rst_v, drn_v, rec_v) == (rst_b, drn_b, rec_b)
    assert res_v.n_completed == len(arrivals)


@pytest.mark.parametrize("policy", ["jffs", "priority"])
def test_cross_backend_reconfigure_dedicated_and_priority(policy):
    arrivals = poisson_arrivals(4.5, 4_000, random.Random(13))
    t_half = arrivals[2000][0]
    results = []
    for engine in ("vector", "batched"):
        sim = make_engine(engine, RATES, CAPS, policy=policy, seed=14,
                          keys=["a", "b", "c"])
        sim.add_arrivals(arrivals)
        sim.run_until(t_half)
        sim.reconfigure([1.0, 0.5], [2, 4], at_time=t_half, keys=["a", "c"])
        sim.run_to_completion()
        results.append(sim.result(warmup_fraction=0.0))
    _identical(results[0], results[1])
    assert results[0].n_completed == len(arrivals)


# ---------------------------------------------------------------------------
# The compiled fast path (jax present): forced onto small traces
# ---------------------------------------------------------------------------

@needs_jax
def test_scan_path_engaged_and_identical():
    t, w = poisson_exponential_np(5.0, 3_000, seed=0)
    v, b = _pair("jffc", scan_min=1)
    v.add_arrivals(t, w)
    b.add_arrivals(t, w)
    assert b._scan_eligible()
    v.run_to_completion()
    b.run_to_completion()
    _identical(v.result(), b.result())
    assert v.comp == b.comp                  # completion order, exactly
    assert b.i == b.n and b.in_flight == 0


@needs_jax
def test_scan_path_tie_breaking():
    """Crafted integer-grid trace with identical works: bitwise-equal
    finish times across slots force the (finish, seq) tie-break."""
    n = 600
    t = np.arange(n, dtype=np.float64) * 0.125
    w = np.ones(n, dtype=np.float64)
    servers = [(1.0, 2), (0.5, 2), (0.25, 1)]
    a = simulate_vectorized("jffc", servers, (t, w), seed=1,
                            warmup_fraction=0.0, engine="vector")
    sim = BatchedEngine([m for m, _ in servers], [c for _, c in servers],
                        policy="jffc", seed=2)
    sim.scan_min_jobs = 1
    sim.add_arrivals(t, w)
    sim.run_to_completion()
    _identical(a, sim.result(warmup_fraction=0.0))


@needs_jax
def test_scan_path_resumes_from_paused_state():
    """run_until leaves in-flight work on the heap; the scan must seed its
    slot state from it and still match the interpreter bit for bit."""
    arrivals = poisson_arrivals(4.8, 5_000, random.Random(5))
    horizon = arrivals[-1][0]
    v, b = _pair("jffc", seed=6, scan_min=1)
    v.add_arrivals(arrivals)
    b.add_arrivals(arrivals)
    v.run_until(0.4 * horizon)
    b.run_until(0.4 * horizon)               # finite horizon: interpreter
    assert b.in_flight > 0
    v.run_to_completion()
    b.run_to_completion()                    # resumes via the compiled path
    _identical(v.result(), b.result())
    assert v.comp == b.comp


@needs_jax
def test_run_seed_grid_matches_per_seed_engines():
    """The one-pass vmapped grid == one engine per seed, bit for bit."""
    lam, n, S = 4.8, 2_000, 6
    traces = [poisson_exponential_np(lam, n, seed=s) for s in range(S)]
    grid = run_seed_grid(RATES, CAPS,
                         np.stack([t for t, _ in traces]),
                         np.stack([w for _, w in traces]),
                         warmup_fraction=0.1)
    assert len(grid) == S
    for (t, w), res in zip(traces, grid):
        one = simulate_vectorized("jffc", SERVERS, (t, w), seed=9,
                                  engine="vector")
        _identical(one, res)


def test_batched_without_scan_still_batched_engine():
    """Below the scan threshold (or without jax) the batched backend is
    the interpreter in disguise — same results, same telemetry taps."""
    t, w = poisson_exponential_np(5.0, 500, seed=3)
    v, b = _pair("jffc", seed=4)
    assert b.scan_min_jobs > 500             # default threshold: fallback
    v.add_arrivals(t, w)
    b.add_arrivals(t, w)
    v.run_to_completion()
    b.run_to_completion()
    _identical(v.result(), b.result())
    assert v.total_capacity == b.total_capacity
    assert v.completions_since(0) == b.completions_since(0)
