"""Geo-distributed serving: topology, routing, partitions, parity gates.

The two CI-gated invariants live here:

* **single-region parity anchor** — a one-region ``RegionSpec`` with a
  zero latency matrix feeds the engine bitwise the arrays the plain
  single-cluster path feeds it, on both engines and both RNG schemes;
* **conservation** — any partition/heal (+ burst/evacuation) timeline
  loses no request: ``partition_lost_requests == 0`` and
  ``completed_all``, with deferred work rerouted on heal.
"""
import json
import math
import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.api as api
from repro.api import (
    ClusterSpec,
    PolicySpec,
    ExperimentSpec,
    RegionSpec,
    ResultsStore,
    ScenarioSpec,
    SpecError,
    WorkloadSpec,
    preset,
    spec_replace,
)
from repro.core.scenarios import Scenario
from repro.geo import GeoArrivals, RegionTopology, execute_geo
from repro.geo.routing import make_router

RING = dict(names=("us", "eu", "ap"),
            latency=((0.0, 0.1, 0.2), (0.1, 0.0, 0.1), (0.2, 0.1, 0.0)))


def _servers(n, seed=1234):
    from repro.core.servers import Server
    rng = random.Random(seed)
    return tuple(Server(f"s{i}", rng.uniform(15, 40), rng.uniform(0.02, 0.2),
                        rng.uniform(0.02, 0.2)) for i in range(n))


def _service():
    from repro.core.servers import ServiceSpec
    return ServiceSpec(num_blocks=10, block_size_gb=1.32, cache_size_gb=0.11)


def _geo_spec(sc: Scenario, router: str = "latency", base_rate: float = 5.0,
              engine: str = "vector", **spec_kw) -> ExperimentSpec:
    return ExperimentSpec(
        cluster=ClusterSpec(job_servers=((1.0, 6),), engine=engine,
                            regions=RegionSpec(router=router, **RING)),
        scenario=ScenarioSpec.from_scenario(sc),
        workload=WorkloadSpec(base_rate=base_rate),
        **spec_kw)


def _raw_geo(spec):
    return execute_geo(spec, spec.scenario.to_scenario())


# ---------------------------------------------------------------------------
# Topology + spec validation
# ---------------------------------------------------------------------------

def test_topology_validation():
    with pytest.raises(ValueError, match="diag|local"):
        RegionTopology(names=("a", "b"), latency=((1.0, 0.0), (0.0, 0.0)))
    with pytest.raises(ValueError, match="matrix"):
        RegionTopology(names=("a", "b"), latency=((0.0,),))
    with pytest.raises(ValueError, match="unique"):
        RegionTopology(names=("a", "a"), latency=((0.0, 0.0), (0.0, 0.0)))
    with pytest.raises(ValueError, match="finite"):
        RegionTopology(names=("a", "b"),
                       latency=((0.0, -1.0), (1.0, 0.0)))
    with pytest.raises(ValueError, match="capacity"):
        RegionTopology(names=("a", "b"),
                       latency=((0.0, 1.0), (1.0, 0.0)), capacity=(1.0,))


def test_topology_weights_normalize():
    topo = RegionTopology(names=("a", "b"),
                          latency=((0.0, 1.0), (1.0, 0.0)),
                          source_weights=(3.0, 1.0))
    assert np.allclose(topo.weights(), [0.75, 0.25])
    assert math.isclose(sum(topo.source_weights), 1.0)
    # default: uniform
    topo = RegionTopology(names=("a", "b"), latency=((0.0, 1.0), (1.0, 0.0)))
    assert np.allclose(topo.weights(), [0.5, 0.5])


def test_region_spec_json_roundtrip():
    spec = preset("region_partition")
    d = spec.to_dict()
    assert d["cluster"]["regions"]["names"] == ["us", "eu", "ap"]
    assert ExperimentSpec.from_dict(d) == spec
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    # the field is optional: non-geo specs don't emit it (old JSON loads)
    plain = preset("mmc_queue")
    assert "regions" not in plain.to_dict()["cluster"]
    assert ExperimentSpec.from_dict(plain.to_dict()) == plain


def test_geo_spec_validation():
    # partition must cut a strict subset
    with pytest.raises(SpecError, match="partition"):
        _geo_spec(Scenario(horizon=50.0)
                  .region_partition(10.0, 5.0, ("us", "eu", "ap")))
    # unknown region names are caught at spec build
    with pytest.raises(SpecError, match="unknown"):
        _geo_spec(Scenario(horizon=50.0).region_burst(5.0, 5.0, 2.0, "mars"))
    # evacuating every region leaves no survivor
    with pytest.raises(SpecError, match="evacuat"):
        _geo_spec(Scenario(horizon=50.0)
                  .region_evacuate(5.0, "us").region_evacuate(5.0, "eu")
                  .region_evacuate(5.0, "ap"))
    # single-cluster events target one cluster, not a fleet
    servers = _servers(4)
    service = _service()
    with pytest.raises(SpecError, match="single cluster"):
        ExperimentSpec(
            cluster=ClusterSpec(
                servers=servers, service=service,
                regions=RegionSpec(names=("us", "eu"),
                                   latency=((0.0, 0.1), (0.1, 0.0)))),
            scenario=ScenarioSpec.from_scenario(
                Scenario(horizon=50.0).fail(5.0, "s0")),
            workload=WorkloadSpec(base_rate=2.0))
    # region events need a topology to name regions in
    with pytest.raises(SpecError, match="regions"):
        ExperimentSpec(
            cluster=ClusterSpec(job_servers=((1.0, 4),)),
            scenario=ScenarioSpec.from_scenario(
                Scenario(horizon=50.0).region_burst(5.0, 5.0, 2.0, "us")),
            workload=WorkloadSpec(base_rate=2.0))
    # ... and so do geo workload generators
    with pytest.raises(SpecError, match="generator"):
        ExperimentSpec(
            cluster=ClusterSpec(job_servers=((1.0, 4),)),
            scenario=ScenarioSpec(horizon=50.0),
            workload=WorkloadSpec(generator="geo-follow-the-sun",
                                  base_rate=2.0,
                                  params={"n_regions": 3}))


# ---------------------------------------------------------------------------
# Routers
# ---------------------------------------------------------------------------

def _ring_topo():
    return RegionTopology(**RING, cost=(1.0, 2.0, 0.5))


def test_latency_router_keeps_traffic_home():
    r = make_router("latency", _ring_topo())
    for src in range(3):
        assert r.pick(src, [0, 1, 2], None) == src
    # home unreachable: nearest survivor
    assert r.pick(0, [1, 2], None) == 1
    assert r.pick(2, [0, 1], None) == 1


def test_cost_router_prefers_cheap():
    r = make_router("cost", _ring_topo())
    assert r.pick(0, [0, 1, 2], None) == 2          # ap is cheapest
    assert r.pick(0, [0, 1], None) == 0


def test_round_robin_cycles_globally():
    r = make_router("round-robin", _ring_topo())
    assert [r.pick(0, [0, 1, 2], None) for _ in range(4)] == [0, 1, 2, 0]
    # the counter persists across candidate-set changes
    assert r.pick(0, [0, 1], None) == 0


def test_load_router_follows_snapshot():
    r = make_router("load", _ring_topo())
    assert r.needs_load and not r.static
    loads = np.asarray([5.0, 0.5, 5.0])
    assert r.pick(0, [0, 1, 2], loads) == 1
    assert r.pick(0, [0, 1, 2], None) == 0          # no snapshot: latency


def test_router_assign_matches_pick_stream():
    sources = np.asarray([0, 2, 1, 1, 0, 2, 2, 0], dtype=np.int64)
    cand = [0, 1, 2]
    for name in ("latency", "cost", "round-robin"):
        va = make_router(name, _ring_topo()).assign(sources, cand)
        seq_router = make_router(name, _ring_topo())
        seq = [seq_router.pick(int(s), cand, None) for s in sources]
        assert va.tolist() == seq, name


def test_unknown_router_rejected():
    with pytest.raises(ValueError, match="unknown geo router"):
        make_router("teleport", _ring_topo())
    with pytest.raises(SpecError, match="router"):
        _geo_spec(Scenario(horizon=50.0), router="teleport")


# ---------------------------------------------------------------------------
# The single-region parity anchor (CI gate)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["vector", "batched"])
@pytest.mark.parametrize("rng_scheme", ["legacy", "counter"])
def test_single_region_bit_parity(engine, rng_scheme):
    """One region + zero latency + no region events == the plain
    single-cluster path, array for array."""
    from repro.api.planes import _execute_precomposed, _resolve_workload

    policy = PolicySpec(name="jsq" if rng_scheme == "counter" else "jffc")
    plain = ExperimentSpec(
        cluster=ClusterSpec(job_servers=((1.0, 5),), engine=engine),
        scenario=ScenarioSpec.from_scenario(
            Scenario(horizon=120.0).burst(30.0, 20.0, 2.0)),
        workload=WorkloadSpec(base_rate=4.0),
        policy=policy, rng_scheme=rng_scheme, warmup_fraction=0.1)
    geo = spec_replace(plain, "cluster.regions",
                       RegionSpec(names=("solo",), latency=((0.0,),)))
    scenario = plain.scenario.to_scenario()
    arr = _resolve_workload(plain, scenario, None)
    res_plain, _ = _execute_precomposed(plain, scenario, arr)
    res_geo, _, extras, _, _ = _raw_geo(geo)
    a, b = res_plain.result, res_geo.result
    assert np.array_equal(a.response_times, b.response_times)
    assert np.array_equal(a.waiting_times, b.waiting_times)
    assert np.array_equal(a.service_times, b.service_times)
    assert np.array_equal(a.class_ids, b.class_ids)
    assert a.sim_time == b.sim_time
    assert a.n_completed == b.n_completed > 0
    assert extras["partition_lost_requests"] == 0
    assert extras["mean_network_latency"] == 0.0


# ---------------------------------------------------------------------------
# Arrival generation
# ---------------------------------------------------------------------------

def test_region_burst_shapes_only_its_region():
    from repro.geo.executor import resolve_geo_arrivals

    topo = RegionTopology(**RING)
    quiet = _geo_spec(Scenario(horizon=200.0))
    burst = _geo_spec(Scenario(horizon=200.0)
                      .region_burst(50.0, 100.0, 4.0, "eu"))
    ga_q = resolve_geo_arrivals(quiet, quiet.scenario.to_scenario(),
                                None, topo)
    ga_b = resolve_geo_arrivals(burst, burst.scenario.to_scenario(),
                                None, topo)
    per_q = {r: ga_q.times[ga_q.sources == r] for r in range(3)}
    per_b = {r: ga_b.times[ga_b.sources == r] for r in range(3)}
    # the burst region gets more arrivals; the others' streams are
    # untouched (independent per-region seeds)
    assert len(per_b[1]) > 1.5 * len(per_q[1])
    assert np.array_equal(per_q[0], per_b[0])
    assert np.array_equal(per_q[2], per_b[2])


def test_follow_the_sun_generator_sources_all_regions():
    spec = preset("follow_the_sun", horizon=120.0)
    rep = api.run(spec)
    ex = rep.extras["geo"]
    assert rep.completed_all and ex["partition_lost_requests"] == 0
    assert sum(ex["sourced"].values()) == rep.n_jobs
    assert sum(ex["routed"].values()) == rep.n_jobs
    assert all(v > 0 for v in ex["sourced"].values())
    # latency routing with every region up serves everything locally
    assert ex["mean_network_latency"] == 0.0
    assert ex["sourced"] == ex["routed"]


def test_geo_arrivals_override_roundtrip():
    """The arrivals= escape hatch: the same GeoArrivals trace through two
    routers — source labels validated, per-router routing differs."""
    spec = preset("follow_the_sun", horizon=120.0)
    from repro.api.planes import _resolve_workload
    ga = _resolve_workload(spec, spec.scenario.to_scenario(), None)
    assert isinstance(ga, GeoArrivals)
    rep_lat = api.run(spec, arrivals=ga)
    rep_rr = api.run(preset("follow_the_sun", router="round-robin",
                            horizon=120.0), arrivals=ga)
    assert rep_lat.n_jobs == rep_rr.n_jobs == len(ga)
    assert rep_lat.extras["geo"]["sourced"] == rep_rr.extras["geo"]["sourced"]
    assert rep_lat.extras["geo"]["mean_network_latency"] < \
        rep_rr.extras["geo"]["mean_network_latency"]
    bad = GeoArrivals(ga.times, ga.works,
                      np.full(len(ga), 7, dtype=np.int64))
    with pytest.raises(ValueError, match="region"):
        api.run(spec, arrivals=bad)


# ---------------------------------------------------------------------------
# Partitions, evacuation, conservation (CI gate)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["vector", "batched"])
def test_partition_preset_conserves_requests(engine):
    spec = preset("region_partition", horizon=150.0, engine=engine)
    res, _, extras, _, _ = _raw_geo(spec)
    assert res.completed_all
    assert extras["partition_lost_requests"] == 0
    assert res.n_jobs == sum(r["n_completed"]
                             for r in extras["per_region"].values())
    kinds = [e.kind for e in res.log]
    assert kinds.count("region_partition") == 1
    assert kinds.count("region_heal") == 1
    assert kinds.count("region_evacuate") == 1


def test_partition_defers_and_reroutes_on_heal():
    """Evacuate eu, then cut it off entirely: eu's sources have nowhere
    to go until heal — deferred, then delivered no earlier than the heal
    boundary, none lost."""
    sc = (Scenario(horizon=120.0)
          .region_evacuate(10.0, "eu")
          .region_partition(30.0, 40.0, ("eu",)))
    spec = _geo_spec(sc, base_rate=4.0)
    res, _, extras, _, _ = _raw_geo(spec)
    assert extras["n_deferred"] > 0
    assert extras["partition_lost_requests"] == 0
    assert res.completed_all
    assert extras["per_region"]["eu"]["n_routed"] < extras["sourced"]["eu"]


def test_evacuated_region_receives_nothing():
    sc = Scenario(horizon=100.0).region_evacuate(0.0, "ap")
    spec = _geo_spec(sc, base_rate=4.0)
    res, _, extras, _, _ = _raw_geo(spec)
    assert extras["routed"]["ap"] == 0
    assert extras["sourced"]["ap"] > 0
    assert res.completed_all and extras["partition_lost_requests"] == 0


def _conservation_case(start, duration, cut, seed):
    sc = Scenario(horizon=100.0).region_partition(
        start, duration, cut)
    spec = _geo_spec(sc, base_rate=4.0, seed=seed)
    res, _, extras, _, _ = _raw_geo(spec)
    assert res.completed_all, (start, duration, cut, seed)
    assert extras["partition_lost_requests"] == 0, (start, duration, cut,
                                                    seed)
    assert res.n_rejected == 0


@settings(max_examples=15, deadline=None)
@given(start=st.floats(0.0, 80.0), duration=st.floats(1.0, 60.0),
       cut=st.sampled_from([("us",), ("eu",), ("ap",), ("us", "eu"),
                            ("eu", "ap"), ("us", "ap")]),
       seed=st.integers(0, 20))
def test_partition_conservation_property(start, duration, cut, seed):
    """Any partition/heal timeline conserves requests."""
    _conservation_case(start, duration, cut, seed)


def test_partition_conservation_sampled():
    """Deterministic twin of the property test (hypothesis optional)."""
    rng = random.Random(7)
    cuts = [("us",), ("eu",), ("ap",), ("us", "eu"), ("eu", "ap")]
    for _ in range(6):
        _conservation_case(rng.uniform(0.0, 80.0), rng.uniform(1.0, 60.0),
                           rng.choice(cuts), rng.randrange(20))


def test_overlapping_partitions_conserve():
    sc = (Scenario(horizon=120.0)
          .region_partition(20.0, 50.0, ("us",))
          .region_partition(40.0, 50.0, ("ap",)))
    spec = _geo_spec(sc, base_rate=4.0)
    res, _, extras, _, _ = _raw_geo(spec)
    assert res.completed_all and extras["partition_lost_requests"] == 0


# ---------------------------------------------------------------------------
# Composed clusters, capacity multipliers
# ---------------------------------------------------------------------------

def test_composed_cluster_per_region():
    """Regions compose their own chains (tuned-c -> GBP-CR -> GCA); a
    capacity multiplier scales the composed total rate by exactly that
    factor."""
    servers = _servers(8)
    service = _service()
    spec = ExperimentSpec(
        cluster=ClusterSpec(
            servers=servers, service=service,
            regions=RegionSpec(names=("big", "small"),
                               latency=((0.0, 0.1), (0.1, 0.0)),
                               capacity=(1.0, 0.5))),
        scenario=ScenarioSpec(horizon=100.0),
        workload=WorkloadSpec(base_rate=2.0))
    res, n_final, extras, _, _ = _raw_geo(spec)
    assert res.completed_all and extras["partition_lost_requests"] == 0
    assert n_final == 16                    # every region owns a full copy


# ---------------------------------------------------------------------------
# Autoscale: per-region controllers, one global budget
# ---------------------------------------------------------------------------

def test_autoscale_global_budget():
    from repro.api import AutoscaleSpec
    from repro.core.servers import Server

    spec = ExperimentSpec(
        cluster=ClusterSpec(
            servers=_servers(4), service=_service(),
            regions=RegionSpec(router="latency", **RING)),
        scenario=ScenarioSpec(horizon=150.0),
        workload=WorkloadSpec(base_rate=2.0),
        autoscale=AutoscaleSpec(policy="target-util",
                                template=Server("tmpl", 30.0, 0.05, 0.05),
                                max_servers=8, min_servers=1,
                                interval=10.0))
    res, n_final, extras, _, _ = _raw_geo(spec)
    assert extras["partition_lost_requests"] == 0
    # growth is capped by the fleet-wide budget (the initial fleet may
    # already exceed it; the budget gates growth, not the starting state)
    assert extras["fleet_servers_final"] <= max(8, 3 * 4)
    assert set(extras["cost_per_region"]) == {"us", "eu", "ap"}
    assert set(extras["scaling_records"]) == {"us", "eu", "ap"}


# ---------------------------------------------------------------------------
# Observability: merged trace lanes + metrics
# ---------------------------------------------------------------------------

def test_geo_trace_and_metrics():
    spec = preset("region_partition", horizon=100.0)
    rep = api.run(spec, trace=True)
    lanes = rep.trace.lanes
    labels = list(lanes.values())
    assert any(l.startswith("us/") for l in labels)
    assert any(l.startswith("eu/") for l in labels)
    assert any(l.startswith("ap/") for l in labels)
    marker_names = {m.name for m in rep.trace.markers}
    assert "region-partition" in marker_names
    assert "region-heal" in marker_names
    assert "region-evacuate" in marker_names
    metrics = rep.extras["metrics"]
    assert metrics["geo.lost"] == 0
    n_routed = sum(metrics[f"geo.routed.{r}"] for r in ("us", "eu", "ap"))
    assert n_routed == rep.n_jobs


# ---------------------------------------------------------------------------
# The batched vmap-over-regions fast path
# ---------------------------------------------------------------------------

def test_fast_path_bit_identical(monkeypatch):
    import repro.geo.grid as gg
    from repro.core.engines.batched import jax_available

    if not jax_available():
        pytest.skip("the grid fast path needs the compiled kernels")
    spec = preset("follow_the_sun", horizon=120.0, engine="batched")
    res_f, _, ex_f, _, _ = _raw_geo(spec)
    monkeypatch.setattr(gg, "try_geo_grid", lambda *a, **k: None)
    res_s, _, ex_s, _, _ = _raw_geo(spec)
    monkeypatch.undo()
    a, b = res_f.result, res_s.result
    assert ex_f["fast_path"] and not ex_s["fast_path"]
    assert np.array_equal(a.response_times, b.response_times)
    assert np.array_equal(a.waiting_times, b.waiting_times)
    assert np.array_equal(a.service_times, b.service_times)
    assert a.sim_time == b.sim_time
    assert ex_f["per_region"] == ex_s["per_region"]
    assert ex_f["routed"] == ex_s["routed"]


def test_fast_path_falls_back_when_regions_interact():
    spec = preset("region_partition", horizon=100.0, engine="batched")
    _, _, extras, _, _ = _raw_geo(spec)
    assert extras["fast_path"] is False     # partitions are boundaries
    spec = preset("follow_the_sun", horizon=100.0, router="load",
                  engine="batched")
    _, _, extras, _, _ = _raw_geo(spec)
    assert extras["fast_path"] is False     # load snapshots re-freeze


# ---------------------------------------------------------------------------
# Sweep grouping over optional spec fields (the ResultsStore regression)
# ---------------------------------------------------------------------------

def test_sweep_regionspec_field_roundtrips_store(tmp_path):
    """A grid over a RegionSpec field must not collapse into the one-pass
    stacked kernel (which cannot model it) and must round-trip losslessly
    through the ResultsStore."""
    spec = preset("follow_the_sun", horizon=100.0, engine="batched")
    store = ResultsStore(str(tmp_path / "store"))
    grid = {"cluster.regions.router": ["latency", "round-robin"]}
    pts = api.sweep(spec, grid, store=store)
    assert len(pts) == 2
    for p in pts:
        assert "swept_one_pass" not in p.report.extras
        assert p.report.extras["geo"]["router"] == \
            p.overrides["cluster.regions.router"]
    assert pts[0].report.p99() != pts[1].report.p99()
    # second pass: every point served from the cache, values preserved
    pts2 = api.sweep(spec, grid, store=store)
    for p, q in zip(pts, pts2):
        assert q.report.p99() == p.report.p99()
        assert q.report.extras["geo"]["router"] == \
            p.report.extras["geo"]["router"]


def test_sweep_seed_grid_still_one_pass(tmp_path):
    """The residual guard must not regress the eligible fast path."""
    from repro.core.engines.batched import jax_available

    if not jax_available():
        pytest.skip("jax unavailable; one-pass sweep cannot compile")
    spec = preset("mmc_queue", n_jobs=3000, engine="batched")
    pts = api.sweep(spec, {"seed": [0, 1]})
    assert all(p.report.extras.get("swept_one_pass") for p in pts)
