"""The jax execution plane under CI: ``LivePlane(engine="jax")`` driven by
``repro.api.run`` on a reduced real model.

The ROADMAP's open item: the jax plane existed but was never exercised by
CI — only the mock engine was.  This smoke keeps it honest: one small
declarative spec, real chain engines jit-stepping a 2-layer stablelm
reduction, every request decoded to completion through the same
spec/workload/report path the mock plane uses.  Skips cleanly when jax is
not installed (the minimal dependency matrix).
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro import api                                        # noqa: E402
from repro.configs import get                                # noqa: E402
from repro.core import Server                                # noqa: E402
from repro.models import Model                               # noqa: E402
from repro.serving import service_spec_for                   # noqa: E402


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get("stablelm-1.6b").reduced(num_layers=2, vocab_size=256)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    service = service_spec_for(cfg, max_seq=64)
    return cfg, model, params, service


def test_jax_plane_runs_spec_end_to_end(tiny_model):
    cfg, model, params, service = tiny_model
    model_gb = service.block_size_gb * cfg.num_layers
    servers = tuple(
        Server(f"srv{i}",
               model_gb + service.cache_size_gb * cfg.num_layers * 5,
               0.02, 0.01 * (1 + i % 2))
        for i in range(3))
    spec = api.ExperimentSpec(
        cluster=api.ClusterSpec(servers=servers, service=service),
        scenario=api.ScenarioSpec(horizon=8.0),
        workload=api.WorkloadSpec(generator="poisson", base_rate=1.5,
                                  params={"n": 5}),
        seed=0, name="jax-plane-smoke")
    plane = api.LivePlane(engine="jax", model=model, params=params,
                          dt=1.0, max_seq=64, prompt_tokens=6,
                          tokens_per_work=4.0)
    rep = api.run(spec, plane=plane)
    assert rep.plane == "live"
    assert rep.completed_all, rep.summary_line()
    assert rep.n_completed == rep.n_jobs == 5
    assert rep.n_failed == 0
    assert np.isfinite(rep.response["mean"])
    # the engines really decoded: every finished request carries output
    orch = rep.extras["orchestrator"]
    assert all(r.output for r in orch.finished)


def test_jax_plane_requires_model_and_params():
    with pytest.raises(ValueError, match="model"):
        api.LivePlane(engine="jax")


def _smoke_spec(service):
    cfg_servers = tuple(
        Server(f"srv{i}", service.block_size_gb * 2
               + service.cache_size_gb * 2 * 5, 0.02, 0.01 * (1 + i % 2))
        for i in range(3))
    return api.ExperimentSpec(
        cluster=api.ClusterSpec(servers=cfg_servers, service=service),
        scenario=api.ScenarioSpec(horizon=8.0),
        workload=api.WorkloadSpec(generator="poisson", base_rate=1.5,
                                  params={"n": 5}),
        seed=0, name="jax-plane-smoke")


def test_jax_plane_paged_layout_bit_identical_to_slotted(tiny_model):
    """The kv_layout parity contract through the full API path: identical
    spec, identical workload, greedy token streams bit-identical between
    the slotted and paged data planes."""
    cfg, model, params, service = tiny_model
    spec = _smoke_spec(service)
    streams = {}
    for layout in ("slotted", "paged"):
        plane = api.LivePlane(engine="jax", model=model, params=params,
                              dt=1.0, max_seq=64, prompt_tokens=6,
                              tokens_per_work=4.0, kv_layout=layout)
        rep = api.run(spec, plane=plane)
        assert rep.completed_all, rep.summary_line()
        orch = rep.extras["orchestrator"]
        streams[layout] = {r.rid: list(r.output) for r in orch.finished}
    assert streams["slotted"] == streams["paged"]


def test_live_plane_kv_layout_knob():
    """Spec validation, store-key visibility, and JSON round-trip."""
    from repro.api.spec import SpecError

    with pytest.raises(SpecError, match="kv_layout"):
        api.LivePlane(kv_layout="interleaved")
    with pytest.raises(SpecError, match="page_size"):
        api.LivePlane(kv_layout="paged", page_size=24)
    with pytest.raises(SpecError, match="page_size"):
        api.LivePlane(kv_layout="paged", page_size=16, max_seq=200)
    with pytest.raises(SpecError, match="oversubscribe"):
        api.LivePlane(kv_layout="paged", oversubscribe=0.5)
    slotted = api.LivePlane()
    paged = api.LivePlane(kv_layout="paged", page_size=32, oversubscribe=2.0)
    # distinct layouts must never share a results-store entry
    assert slotted.store_key() != paged.store_key()
    assert "kv_layout=paged" in paged.store_key()
    assert "page_size=32" in paged.store_key()
    # JSON round-trip preserves every knob
    import json

    d = json.loads(json.dumps(paged.to_dict()))
    clone = api.LivePlane.from_dict(d)
    assert clone.to_dict() == paged.to_dict()
    assert clone.store_key() == paged.store_key()
    with pytest.raises(SpecError, match="unknown"):
        api.LivePlane.from_dict({"plane": "live", "kv_format": "paged"})
