"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes/dtypes (hypothesis) + hand-picked hard cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.ops import (
    decode_attention,
    flash_attention,
    paged_decode_attention,
)

TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _mk_qkv(key, B, S, H, KV, hd, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32).astype(dtype)
    return q, k, v


@settings(max_examples=12, deadline=None)
@given(
    B=st.sampled_from([1, 2]),
    S=st.sampled_from([128, 256, 512]),
    heads=st.sampled_from([(4, 4), (4, 2), (8, 1)]),
    hd=st.sampled_from([64, 128]),
    window=st.sampled_from([0, 128]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 99),
)
def test_flash_attention_matches_ref(B, S, heads, hd, window, dtype, seed):
    H, KV = heads
    q, k, v = _mk_qkv(jax.random.PRNGKey(seed), B, S, H, KV, hd, dtype)
    out = flash_attention(q, k, v, causal=True, window=window,
                          use_pallas=True, block_q=128, block_k=128, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **TOL[dtype])


def test_flash_attention_non_square_blocks():
    q, k, v = _mk_qkv(jax.random.PRNGKey(0), 2, 512, 4, 2, 64, jnp.float32)
    out = flash_attention(q, k, v, use_pallas=True, block_q=256, block_k=128,
                          interpret=True)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-4, atol=2e-4)


def test_flash_attention_noncausal():
    q, k, v = _mk_qkv(jax.random.PRNGKey(1), 1, 256, 4, 4, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=False, use_pallas=True,
                          block_q=128, block_k=128, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-4, atol=2e-4)


@settings(max_examples=12, deadline=None)
@given(
    B=st.sampled_from([1, 3]),
    S=st.sampled_from([512, 1024]),
    heads=st.sampled_from([(4, 4), (8, 2), (7, 1)]),
    hd=st.sampled_from([64, 128]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 99),
)
def test_decode_attention_matches_ref(B, S, heads, hd, dtype, seed):
    H, KV = heads
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32).astype(dtype)
    kc = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32).astype(dtype)
    vc = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32).astype(dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, S + 1)
    out = decode_attention(q, kc, vc, lengths, use_pallas=True,
                           block_s=256, interpret=True)
    expect = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **TOL[dtype])


def test_decode_attention_length_edge_cases():
    """lengths = 1 (only first entry valid) and lengths = S (all valid)."""
    B, S, H, KV, hd = 2, 512, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    kc = jax.random.normal(ks[1], (B, S, KV, hd))
    vc = jax.random.normal(ks[2], (B, S, KV, hd))
    for lengths in (jnp.array([1, 1]), jnp.array([S, S]), jnp.array([1, S])):
        out = decode_attention(q, kc, vc, lengths, use_pallas=True,
                               block_s=128, interpret=True)
        expect = ref.decode_attention_ref(q, kc, vc, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-4, atol=2e-4)


def _mk_paged(seed, B, P, PP, page, KV, hd, H, dtype=jnp.float32):
    """Random pools + a permuted block table: pages deliberately land in
    scattered, non-contiguous pool rows; unused tail entries are -1."""
    rng = np.random.default_rng(seed)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (P, page, KV, hd), jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (P, page, KV, hd), jnp.float32).astype(dtype)
    bt = np.full((B, PP), -1, np.int32)
    lengths = np.zeros((B,), np.int32)
    perm = rng.permutation(P)
    k = 0
    for b in range(B):
        n = int(rng.integers(1, PP + 1))
        bt[b, :n] = perm[k:k + n]
        k += n
        lengths[b] = int(rng.integers(1, n * page + 1))
    return q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths)


@settings(max_examples=12, deadline=None)
@given(
    B=st.sampled_from([1, 3]),
    page=st.sampled_from([16, 32]),
    PP=st.sampled_from([2, 4]),
    heads=st.sampled_from([(4, 4), (8, 2), (4, 1)]),
    hd=st.sampled_from([64, 128]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 99),
)
def test_paged_decode_attention_matches_ref(B, page, PP, heads, hd, dtype, seed):
    H, KV = heads
    P = B * PP + 3                       # pool larger than any one table
    q, kp, vp, bt, lengths = _mk_paged(seed, B, P, PP, page, KV, hd, H, dtype)
    out = paged_decode_attention(q, kp, vp, bt, lengths,
                                 use_pallas=True, interpret=True)
    expect = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **TOL[dtype])


def test_paged_decode_matches_dense_decode():
    """Gathering pages through the block table computes the same attention
    as the dense kernel over the gathered cache (the layout is invisible)."""
    B, P, PP, page, KV, hd, H = 2, 12, 4, 32, 2, 64, 4
    q, kp, vp, bt, lengths = _mk_paged(11, B, P, PP, page, KV, hd, H)
    paged = paged_decode_attention(q, kp, vp, bt, lengths,
                                   use_pallas=True, interpret=True)
    btc = jnp.maximum(bt, 0)
    k_dense = kp[btc].reshape(B, PP * page, KV, hd)
    v_dense = vp[btc].reshape(B, PP * page, KV, hd)
    dense = decode_attention(q, k_dense, v_dense, lengths,
                             use_pallas=True, block_s=64, interpret=True)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)


def test_paged_decode_length_edge_cases():
    """lengths = 1, a single page, and a full table."""
    B, P, page, KV, hd, H = 2, 6, 16, 2, 64, 4
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (P, page, KV, hd))
    vp = jax.random.normal(ks[2], (P, page, KV, hd))
    for bt, lengths in [
        (jnp.array([[3, -1], [5, 1]]), jnp.array([1, 2 * page])),
        (jnp.array([[2], [4]]), jnp.array([page, 1])),
        (jnp.array([[0, 1], [2, 3]]), jnp.array([2 * page, 2 * page])),
    ]:
        out = paged_decode_attention(q, kp, vp, bt, lengths,
                                     use_pallas=True, interpret=True)
        expect = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-4, atol=2e-4)


def test_ref_agrees_with_model_layer_attention():
    """Kernel oracle vs the model layer's attention implementation (the two
    independent formulations must agree)."""
    from repro.models.layers import attention_full

    q, k, v = _mk_qkv(jax.random.PRNGKey(3), 2, 128, 8, 2, 64, jnp.float32)
    a = ref.flash_attention_ref(q, k, v, causal=True)
    b = attention_full(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_jnp_fallback_path():
    q, k, v = _mk_qkv(jax.random.PRNGKey(4), 1, 128, 4, 4, 64, jnp.float32)
    out = flash_attention(q, k, v, use_pallas=False)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-6, atol=1e-6)
