"""Component-level equivalence tests: recurrent mixers (parallel vs
step-by-step), MoE dispatch (capacity-gather vs dense oracle), attention
(chunked vs full)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig
from repro.models import recurrent
from repro.models.layers import (
    attention_chunked,
    attention_full,
    moe_apply,
    moe_apply_dense_ref,
    moe_init,
)


def rollout_steps(step_fn, params, state, x):
    B, S, D = x.shape
    ys = []
    for t in range(S):
        y, state = step_fn(params, state, x[:, t])
        ys.append(y)
    return jnp.stack(ys, axis=1), state


@pytest.mark.parametrize("S,chunk", [(32, 8), (48, 16), (17, 17)])
def test_mlstm_parallel_equals_recurrent(S, chunk):
    B, D, H, hd = 2, 32, 4, 8
    key = jax.random.PRNGKey(0)
    p = recurrent.mlstm_init(key, D, H, hd, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D))
    y_par, st_par = recurrent.mlstm_parallel(p, x, chunk=chunk)
    y_seq, st_seq = rollout_steps(recurrent.mlstm_step, p,
                                  recurrent.mlstm_zero_state(B, H, hd), x)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_par["C"]), np.asarray(st_seq["C"]),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_state_carry_across_calls():
    """parallel(x1) then parallel(x2, state) == parallel(concat(x1, x2))."""
    B, D, H, hd, S = 1, 16, 2, 8, 32
    p = recurrent.mlstm_init(jax.random.PRNGKey(0), D, H, hd, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D))
    y_full, _ = recurrent.mlstm_parallel(p, x, chunk=8)
    y1, st = recurrent.mlstm_parallel(p, x[:, :16], chunk=8)
    y2, _ = recurrent.mlstm_parallel(p, x[:, 16:], chunk=8, state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)


def test_slstm_parallel_equals_recurrent():
    B, D, H, S = 2, 32, 4, 24
    p = recurrent.slstm_init(jax.random.PRNGKey(0), D, H, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D))
    y_par, _ = recurrent.slstm_parallel(p, x)
    y_seq, _ = rollout_steps(recurrent.slstm_step, p,
                             recurrent.slstm_zero_state(B, D), x)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("S,chunk", [(32, 8), (24, 24)])
def test_ssm_parallel_equals_recurrent(S, chunk):
    B, D, Din, N, W = 2, 16, 24, 4, 4
    p = recurrent.ssm_init(jax.random.PRNGKey(0), D, Din, N, W, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D))
    y_par, st_par = recurrent.ssm_parallel(p, x, chunk=chunk)
    y_seq, st_seq = rollout_steps(recurrent.ssm_step, p,
                                  recurrent.ssm_zero_state(B, Din, N, W), x)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_par["h"]), np.asarray(st_seq["h"]),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    t=st.integers(4, 24),
    e=st.sampled_from([4, 8]),
    k=st.sampled_from([1, 2]),
    seed=st.integers(0, 100),
)
def test_moe_matches_dense_reference(t, e, k, seed):
    """With drop-free capacity, the gather/scatter dispatch must equal the
    dense all-experts oracle."""
    G, D, F = 2, 16, 32
    moe = MoEConfig(num_experts=e, top_k=k, capacity_factor=float(e) / k)
    p = moe_init(jax.random.PRNGKey(seed), D, F, moe, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (G, t, D))
    out = moe_apply(x, p, moe, "swiglu")
    ref = moe_apply_dense_ref(x, p, moe, "swiglu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=5e-4, atol=5e-4)


def test_moe_capacity_drops_bounded():
    """With cf=1.0 some tokens may drop, but output stays finite and the
    drop never exceeds (1 - C*E/(T*k)) of mass."""
    G, T, D, F = 1, 64, 16, 32
    moe = MoEConfig(num_experts=8, top_k=2, capacity_factor=1.0)
    p = moe_init(jax.random.PRNGKey(0), D, F, moe, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (G, T, D))
    out = moe_apply(x, p, moe, "swiglu")
    assert bool(jnp.isfinite(out).all())


@settings(max_examples=8, deadline=None)
@given(
    sq=st.sampled_from([32, 64]),
    kv=st.sampled_from([1, 2, 4]),
    window=st.sampled_from([0, 16]),
    seed=st.integers(0, 50),
)
def test_chunked_attention_equals_full(sq, kv, window, seed):
    B, H, hd = 2, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, sq, H, hd))
    k = jax.random.normal(ks[1], (B, sq, kv, hd))
    v = jax.random.normal(ks[2], (B, sq, kv, hd))
    full = attention_full(q, k, v, causal=True, window=window)
    chunked = attention_chunked(q, k, v, q_chunk=16, k_chunk=16,
                                causal=True, window=window)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=2e-5, atol=2e-5)
