"""Per-architecture smoke tests: reduced config, one forward + one decode on
CPU, asserting shapes and finiteness.  Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED, get
from repro.models import Model

B, S = 2, 64


def make_batch(cfg, key, seq=S, with_labels=True):
    kt, kl, ke = jax.random.split(key, 3)
    batch = {}
    if cfg.family == "vlm":
        P = cfg.num_prefix_embeds
        batch["patch_embeds"] = jax.random.normal(ke, (B, P, cfg.d_model)).astype(cfg.dtype)
        batch["tokens"] = jax.random.randint(kt, (B, seq - P), 0, cfg.vocab_size)
        if with_labels:
            batch["labels"] = jax.random.randint(kl, (B, seq - P), 0, cfg.vocab_size)
    elif cfg.family == "audio":
        batch["embeds"] = jax.random.normal(ke, (B, seq, cfg.d_model)).astype(cfg.dtype)
        if with_labels:
            batch["labels"] = jax.random.randint(kl, (B, seq), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(kt, (B, seq), 0, cfg.vocab_size)
        if with_labels:
            batch["labels"] = jax.random.randint(kl, (B, seq), 0, cfg.vocab_size)
    return batch


def reduced(name):
    cfg = get(name).reduced()
    if cfg.family == "vlm":
        cfg = cfg.__class__(**{**cfg.__dict__, "num_prefix_embeds": 16})
    return cfg


@pytest.mark.parametrize("name", ASSIGNED + ["bloom-176b"])
def test_forward_and_loss(name):
    cfg = reduced(name)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits = jax.jit(model.forward_train)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), f"{name}: NaN in logits"
    loss = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    # loss should be near log(V) at random init
    assert float(loss) < 2.0 * np.log(cfg.vocab_size) + 1.0


@pytest.mark.parametrize("name", ASSIGNED)
def test_grad_step_reduces_loss(name):
    cfg = reduced(name)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(model.loss)(p, batch)
        p2 = jax.tree.map(lambda w, gw: (w.astype(jnp.float32)
                                         - 0.1 * gw.astype(jnp.float32)).astype(w.dtype), p, g)
        return loss, p2

    l0, params = step(params)
    for _ in range(3):
        l1, params = step(params)
    assert np.isfinite(float(l1))
    assert float(l1) < float(l0), f"{name}: loss did not decrease ({l0} -> {l1})"


@pytest.mark.parametrize("name", ASSIGNED)
def test_prefill_decode_matches_forward(name):
    """Prefill + one decode step must agree with running the full sequence
    through the train forward (teacher-forcing consistency)."""
    cfg = reduced(name)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    seq = 32
    batch = make_batch(cfg, jax.random.PRNGKey(1), seq=seq, with_labels=False)

    max_seq = seq + 8
    cache = model.init_cache(B, max_seq)
    last_logits, cache = jax.jit(model.prefill)(params, cache, batch)
    assert last_logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(last_logits.astype(jnp.float32)).all())

    # Forward-path logits at the last position must match prefill's output.
    full_logits = jax.jit(model.forward_train)(params, batch)
    np.testing.assert_allclose(
        np.asarray(last_logits, np.float32),
        np.asarray(full_logits[:, -1], np.float32),
        rtol=2e-2, atol=2e-2,
    )

    # One decode step appends a token; logits must match extending the prompt.
    next_tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    lengths = jnp.full((B,), seq, jnp.int32)
    dec_logits, cache = jax.jit(model.decode_step)(params, cache, next_tok, lengths)
    assert dec_logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(dec_logits.astype(jnp.float32)).all())

    if cfg.family in ("vlm", "audio"):
        return  # extended prompt would need frontend embeds; consistency n/a
    ext = {"tokens": jnp.concatenate([batch["tokens"], next_tok[:, None]], axis=1)}
    ext_logits = jax.jit(model.forward_train)(params, ext)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(ext_logits[:, -1], np.float32),
        rtol=6e-2, atol=6e-2,
    )


def test_stage_structure_examples():
    from repro.models import layer_kind, stages

    ds = get("deepseek-v3-671b")
    st = stages(ds)
    assert [(s.kind, s.count) for s in st] == [("dense", 3), ("moe", 58)]

    hy = get("hymba-1.5b")
    st = stages(hy)
    kinds = [(s.kind, s.count) for s in st]
    assert kinds == [
        ("hybrid_global", 1), ("hybrid_swa", 14), ("hybrid_global", 1),
        ("hybrid_swa", 15), ("hybrid_global", 1),
    ]

    xl = get("xlstm-350m")
    assert layer_kind(xl, 0) == "slstm" and layer_kind(xl, 1) == "mlstm"
    assert sum(s.count for s in stages(xl)) == 24


def test_param_accounting_close_to_nameplate():
    """total_param_count should be within ~20% of each model's nameplate size
    (configs are from public literature; small deltas from impl choices)."""
    expect = {
        "qwen3-8b": 8.2e9, "qwen2-7b": 7.6e9, "stablelm-1.6b": 1.6e9,
        "nemotron-4-15b": 15e9, "internvl2-76b": 76e9, "dbrx-132b": 132e9,
        "deepseek-v3-671b": 671e9, "bloom-176b": 176e9,
    }
    for name, target in expect.items():
        n = get(name).total_param_count()
        assert 0.7 * target < n < 1.45 * target, f"{name}: {n:.3e} vs {target:.3e}"
