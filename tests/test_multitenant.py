"""Multi-tenant SLO-class serving: priority scheduling, aging, admission
control, class-labeled workloads, tenant bursts, and the SLO-aware
admission autoscale policy — across the simulated and live planes."""
import math
import random

import numpy as np
import pytest

from repro.autoscale import (
    AutoscaleAction,
    AutoscaleController,
    AutoscalePolicy,
    ClusterView,
    ControllerConfig,
    SLOAwareAdmissionPolicy,
    Telemetry,
    TelemetryConfig,
)
from conftest import run_scenario_spec as run_scenario
from repro.core import (
    DEFAULT_CLASS,
    RequestClass,
    Scenario,
    Server,
    ServiceSpec,
    VectorSimulator,
    classed_poisson_mix,
    interactive_batch_mix,
    label_classes,
    simulate_vectorized,
)
from repro.core.simulator import poisson_arrivals
from repro.serving import Request, State, mock_orchestrator

SERVERS = [(1.0, 2), (0.8, 2), (0.5, 4)]   # nu = 5.6
RATES = [m for m, _ in SERVERS]
CAPS = [c for _, c in SERVERS]
NU = sum(m * c for m, c in SERVERS)

SPEC = ServiceSpec(num_blocks=10, block_size_gb=1.32, cache_size_gb=0.11)

TWO_CLASSES = [RequestClass("interactive", "chat", 0, slo_target=2.0),
               RequestClass("batch", "offline", 1)]


def mk(sid, mem=16.0, tc=0.05, tp=0.08):
    return Server(sid, mem, tc, tp)


# ---------------------------------------------------------------------------
# Request classes + class-labeled workloads
# ---------------------------------------------------------------------------

def test_request_class_defaults_and_sheddability():
    assert DEFAULT_CLASS.priority == 0
    assert not DEFAULT_CLASS.sheddable
    inter, batch = interactive_batch_mix(batch_deadline=30.0)
    assert inter.priority < batch.priority
    assert batch.sheddable and not inter.sheddable


def test_classed_poisson_mix_rates_and_ordering():
    t, w, c = classed_poisson_mix([3.0, 1.0], 2_000.0, seed=0)
    assert len(t) == len(w) == len(c)
    assert np.all(np.diff(t) >= 0)
    n0, n1 = np.sum(c == 0), np.sum(c == 1)
    assert n0 == pytest.approx(3.0 * 2_000, rel=0.05)
    assert n1 == pytest.approx(1.0 * 2_000, rel=0.08)
    # independent per-class streams: adding a class keeps class-0 arrivals
    t2, _, c2 = classed_poisson_mix([3.0, 2.5], 2_000.0, seed=0)
    assert np.array_equal(t[c == 0], t2[c2 == 0])


def test_label_classes_weights():
    cls = label_classes(50_000, [0.7, 0.3], seed=1)
    assert set(np.unique(cls)) == {0, 1}
    assert np.mean(cls == 0) == pytest.approx(0.7, abs=0.01)
    with pytest.raises(ValueError):
        label_classes(10, [])


def test_tenant_burst_phases_per_class():
    sc = (Scenario(horizon=100.0)
          .burst(10.0, 10.0, 2.0)                 # global
          .tenant_burst(50.0, 20.0, 4.0, cls=1))  # batch only
    ph = sc.class_arrival_phases([1.0, 0.5])
    assert ph[0] == [(0.0, 10.0, 1.0), (10.0, 20.0, 2.0), (20.0, 100.0, 1.0)]
    assert ph[1] == [(0.0, 10.0, 0.5), (10.0, 20.0, 1.0), (20.0, 50.0, 0.5),
                     (50.0, 70.0, 2.0), (70.0, 100.0, 0.5)]
    # class-blind view ignores the tenant burst but keeps the global one
    assert sc.arrival_phases(1.0) == ph[0]
    # tenant_burst events are workload events, not cluster events
    assert sc.cluster_events() == []
    from repro.core import ScenarioEvent
    with pytest.raises(ValueError):
        ScenarioEvent(1.0, "tenant_burst", scale=2.0, duration=5.0)  # no cls


# ---------------------------------------------------------------------------
# Priority engine semantics
# ---------------------------------------------------------------------------

def test_work_conservation_across_classes_single_server():
    """On one single-slot chain the unfinished work at any instant is
    order-invariant, so priority reordering keeps the busy periods — and
    therefore the makespan and total service — of class-blind FIFO."""
    t, w, c = classed_poisson_mix([0.5, 0.3], 3_000.0, seed=3)
    fifo = VectorSimulator([1.0], [1], policy="jffc", seed=4,
                           classes=TWO_CLASSES)
    fifo.add_arrivals(t, w, c)
    fifo.run_to_completion()
    pri = VectorSimulator([1.0], [1], policy="priority", seed=4,
                          classes=TWO_CLASSES, aging_rate=0.0)
    pri.add_arrivals(t, w, c)
    pri.run_to_completion()
    rf, rp = fifo.result(0.0), pri.result(0.0)
    assert rf.n_completed == rp.n_completed == len(t)
    assert rf.sim_time == pytest.approx(rp.sim_time)   # busy periods intact
    assert float(np.sum(rf.service_times)) == pytest.approx(
        float(np.sum(rp.service_times)))


def test_priority_cuts_interactive_latency_under_overload():
    lam = 1.15 * NU
    t, w, c = classed_poisson_mix([0.7 * lam, 0.3 * lam], 2_500.0, seed=5)
    fifo = simulate_vectorized("jffc", SERVERS, (t, w, c), seed=5,
                               classes=TWO_CLASSES, warmup_fraction=0.0)
    pri = simulate_vectorized("priority", SERVERS, (t, w, c), seed=5,
                              classes=TWO_CLASSES, warmup_fraction=0.0)
    p99_fifo = fifo.per_class()[0]["response"]["p99"]
    p99_pri = pri.per_class()[0]["response"]["p99"]
    assert p99_pri < 0.25 * p99_fifo
    # work conservation: nothing lost, nothing shed
    assert pri.n_completed == len(t) and pri.n_rejected == 0


def test_no_starvation_under_aging():
    """A lone batch job in a saturated interactive stream: strict priority
    parks it until the stream ends; aging bounds its wait."""
    interactive = [(0.1 * i, 1.0, 0, 0, 0) for i in range(400)]
    batch_arrival = 1.0
    arrivals = sorted(interactive + [(batch_arrival, 1.0, 0, 0, 1)])
    classes = [RequestClass("interactive", "chat", 0),
               RequestClass("batch", "offline", 1)]

    def batch_wait(aging):
        res = simulate_vectorized("priority", [(1.0, 1)], arrivals, seed=0,
                                  classes=classes, aging_rate=aging,
                                  warmup_fraction=0.0)
        (bidx,) = np.where(res.class_ids == 1)
        return float(res.waiting_times[bidx[0]])

    strict, aged = batch_wait(0.0), batch_wait(0.5)
    assert aged < strict
    # aged key: tier 1 + 0.5*arr beats interactive arriving ~2/0.5 s later,
    # so the wait is bounded well below the full-backlog wait
    assert aged < 0.5 * strict


def test_admission_sheds_only_best_effort_and_bounds_backlog():
    lam = 1.3 * NU
    horizon = 2_000.0
    t, w, c = classed_poisson_mix([0.6 * lam, 0.4 * lam], horizon, seed=6)
    classes = [RequestClass("interactive", "chat", 0, slo_target=2.0),
               RequestClass("batch", "offline", 1, deadline=20.0)]
    sim = VectorSimulator(RATES, CAPS, policy="priority", seed=7,
                          classes=classes, aging_rate=0.001)
    sim.add_arrivals(t, w, c)
    sim.run_to_completion()
    res = sim.result(0.0)
    assert res.n_rejected > 0
    # only the sheddable batch class was rejected
    assert set(res.rejected_class_ids.tolist()) == {1}
    # everything is accounted for: completed + shed == offered
    assert res.n_completed + res.n_rejected == len(t)
    # interactive never shed, never starved
    pc = res.per_class()
    assert pc[0]["rejected"] == 0
    assert pc[0]["n"] == int(np.sum(c == 0))
    # shedding bounds the batch backlog: batch p99 wait far below the
    # no-admission run on the same trace
    open_gate = [classes[0],
                 RequestClass("batch", "offline", 1)]     # deadline = inf
    ref = simulate_vectorized("priority", SERVERS, (t, w, c), seed=6,
                              classes=open_gate, aging_rate=0.001,
                              warmup_fraction=0.0)
    assert pc[1]["waiting"]["p99"] < 0.5 * \
        ref.per_class()[1]["waiting"]["p99"]


def test_admission_level_zero_defers_all_queued_batch():
    lam = 1.2 * NU
    t, w, c = classed_poisson_mix([0.7 * lam, 0.3 * lam], 500.0, seed=8)
    classes = [RequestClass("interactive", "chat", 0),
               RequestClass("batch", "offline", 1, deadline=30.0)]
    sim = VectorSimulator(RATES, CAPS, policy="priority", seed=9,
                          classes=classes, admission_level=0.0)
    sim.add_arrivals(t, w, c)
    sim.run_to_completion()
    res = sim.result(0.0)
    # with the gate closed, every batch job that had to queue was shed
    assert set(res.rejected_class_ids.tolist()) <= {1}
    assert all(res.waiting_times[res.class_ids == 1] == 0.0)


def test_per_class_littles_law_and_throughput():
    """Stable mix: per-class completion rates recover the offered rates,
    and per-class PASTA/Little occupancies are positive and ordered by
    priority (interactive waits less than batch)."""
    lam_int, lam_bat = 2.2, 1.1          # rho ~ 0.59 of nu=5.6
    horizon = 20_000.0
    t, w, c = classed_poisson_mix([lam_int, lam_bat], horizon, seed=10)
    res = simulate_vectorized("priority", SERVERS, (t, w, c), seed=10,
                              classes=TWO_CLASSES, aging_rate=0.0,
                              warmup_fraction=0.1)
    pc = res.per_class()
    span = res.sim_time
    assert pc[0]["n"] / (0.9 * span) == pytest.approx(lam_int, rel=0.05)
    assert pc[1]["n"] / (0.9 * span) == pytest.approx(lam_bat, rel=0.05)
    # Little: lambda_c * E[T_c]; priority orders the occupancies' wait share
    occ_int = lam_int * pc[0]["response"]["mean"]
    occ_bat = lam_bat * pc[1]["response"]["mean"]
    assert occ_int > 0 and occ_bat > 0
    assert pc[0]["waiting"]["mean"] <= pc[1]["waiting"]["mean"]
    # aggregate Little consistency: class occupancies sum to the total
    # (approximate: offered-rate weights vs. realized completion shares)
    total = (lam_int + lam_bat) * res.summary()["response"]["mean"]
    share = (lam_int * pc[0]["response"]["mean"]
             + lam_bat * pc[1]["response"]["mean"])
    assert share == pytest.approx(total, rel=0.02)


def test_priority_reconfigure_loses_no_jobs():
    t, w, c = classed_poisson_mix([2.6, 1.3], 1_000.0, seed=11)
    sim = VectorSimulator(RATES, CAPS, policy="priority", seed=12,
                          classes=TWO_CLASSES, aging_rate=0.01,
                          keys=["a", "b", "c"])
    sim.add_arrivals(t, w, c)
    t_half = float(t[len(t) // 2])
    sim.run_until(t_half)
    sim.reconfigure([1.0, 0.5], [2, 4], at_time=t_half, keys=["a", "c"])
    sim.run_to_completion()
    res = sim.result(0.0)
    assert res.n_completed == len(t)
    assert sim.queue_len() == 0 and sim.in_flight == 0
    assert len(set(sim.comp)) == len(t)


def test_run_scenario_classed_end_to_end():
    rng = random.Random(1234)
    servers = [Server(f"s{i}", rng.uniform(15, 40), rng.uniform(0.02, 0.2),
                      rng.uniform(0.02, 0.2)) for i in range(8)]
    classes = [RequestClass("interactive", "chat", 0, slo_target=5.0),
               RequestClass("batch", "offline", 1, deadline=60.0)]
    sc = (Scenario(horizon=200.0)
          .tenant_burst(50.0, 40.0, 3.0, cls=0)
          .fail(100.0, "s3")
          .recover(150.0, servers[3]))
    res = run_scenario(servers, SPEC, sc, policy="priority",
                       classes=classes, class_rates=[2.0, 1.0],
                       aging_rate=0.001, seed=0)
    assert res.completed_all
    assert res.n_jobs > 0
    pc = res.per_class()
    assert set(pc) == {0, 1}
    assert res.reconfigurations >= 2          # fail + recover


# ---------------------------------------------------------------------------
# Live plane: orchestrator priority dispatch + admission gate
# ---------------------------------------------------------------------------

def _req(rid, cls=0, n_new=6, arrival=0.0):
    return Request(rid=rid, prompt=np.ones(4, np.int32),
                   max_new_tokens=n_new, arrival_time=arrival, cls=cls)


def test_orchestrator_priority_queue_orders_classes():
    classes = [RequestClass("interactive", "chat", 0),
               RequestClass("batch", "offline", 1)]
    orch = mock_orchestrator([mk("b0")], SPEC, arrival_rate=1.0,
                             classes=classes)
    cap = sum(e.capacity for e in orch.engines)
    # fill every slot, then queue batch before interactive
    for i in range(cap):
        orch.submit(_req(i), now=0.0)
    batch = [_req(100 + i, cls=1, arrival=float(i)) for i in range(3)]
    inter = [_req(200 + i, cls=0, arrival=3.0 + i) for i in range(3)]
    for r in batch + inter:
        orch.submit(r, now=r.arrival_time)
    assert len(orch.queue) == 6
    # later-arriving interactive requests outrank queued batch
    order = [r.rid for r in orch.queue]
    assert order[:3] == [200, 201, 202]
    orch.drain()
    assert all(r.state == State.DONE for r in batch + inter)


def test_orchestrator_single_class_fifo_unchanged():
    orch = mock_orchestrator([mk("b0")], SPEC, arrival_rate=1.0)
    cap = sum(e.capacity for e in orch.engines)
    reqs = [_req(i, arrival=float(i)) for i in range(cap + 4)]
    for r in reqs:
        orch.submit(r, now=r.arrival_time)
    assert [r.rid for r in orch.queue] == [cap, cap + 1, cap + 2, cap + 3]
    orch.drain()
    assert all(r.state == State.DONE for r in reqs)


def test_orchestrator_admission_defers_and_readmits():
    classes = [RequestClass("interactive", "chat", 0),
               RequestClass("batch", "offline", 1, deadline=1e-9)]
    orch = mock_orchestrator([mk("b0")], SPEC, arrival_rate=1.0,
                             classes=classes)
    cap = sum(e.capacity for e in orch.engines)
    for i in range(cap):
        orch.submit(_req(i, n_new=4), now=0.0)
    # saturated: the batch request's est. wait exceeds its deadline -> defer
    b = _req(500, cls=1, n_new=4)
    orch.submit(b, now=0.0)
    assert b.state == State.DEFERRED
    assert len(orch.deferred) == 1 and len(orch.queue) == 0
    assert orch.stats()["deferred"] == 1
    orch.drain()
    # the backlog drained, the deferred request was readmitted + completed
    assert b.state == State.DONE
    assert not orch.deferred


def test_orchestrator_admission_level_zero_then_reopen():
    classes = [RequestClass("interactive", "chat", 0),
               RequestClass("batch", "offline", 1, deadline=50.0)]
    orch = mock_orchestrator([mk("b0")], SPEC, arrival_rate=1.0,
                             classes=classes)
    cap = sum(e.capacity for e in orch.engines)
    orch.set_admission_level(0.0)
    for i in range(cap):
        orch.submit(_req(i, n_new=8), now=0.0)
    b = _req(501, cls=1, n_new=4)
    orch.submit(b, now=0.0)
    assert b.state == State.DEFERRED        # gate closed
    orch.set_admission_level(1.0)
    orch.drain()
    assert b.state == State.DONE


# ---------------------------------------------------------------------------
# SLO-aware admission autoscale policy + controller actuation
# ---------------------------------------------------------------------------

class _NoopPolicy(AutoscalePolicy):
    name = "noop"

    def decide(self, tel, view, now):
        return AutoscaleAction(reason="noop")


class _AddOnePolicy(AutoscalePolicy):
    name = "add-one"

    def decide(self, tel, view, now):
        return AutoscaleAction(add=1, reason="inner add")


def _view(admission_level=1.0, n=2):
    return ClusterView(servers=[mk(f"s{i}") for i in range(n)], pending=[],
                       spec=SPEC, rho_bar=0.7, total_rate=4.0,
                       admission_level=admission_level)


def _tel_with_p99(p99_value, queue_depth=0):
    tel = Telemetry(TelemetryConfig(window=60.0))
    for i in range(50):
        tel.record_completion(float(i), p99_value, cls=0)
    tel.record_sample(50.0, queue_depth=queue_depth, in_flight=1,
                      capacity=4, n_servers=2)
    return tel


def test_slo_admission_tightens_before_scaling_out():
    pol = SLOAwareAdmissionPolicy(_AddOnePolicy(), slo=2.0)
    act = pol.decide(_tel_with_p99(5.0), _view(1.0), now=0.0)
    assert act.add == 0 and act.admission_level == 0.5


def test_slo_admission_delegates_when_gate_closed():
    pol = SLOAwareAdmissionPolicy(_AddOnePolicy(), slo=2.0)
    act = pol.decide(_tel_with_p99(5.0), _view(0.0), now=0.0)
    assert act.add == 1 and act.admission_level is None


def test_slo_admission_relaxes_when_healthy():
    pol = SLOAwareAdmissionPolicy(_NoopPolicy(), slo=2.0)
    act = pol.decide(_tel_with_p99(0.5), _view(0.25), now=0.0)
    assert act.admission_level == 0.5
    # fully open + healthy -> transparent to the inner policy
    act2 = pol.decide(_tel_with_p99(0.5), _view(1.0), now=0.0)
    assert act2.is_noop


def test_slo_admission_snaps_to_floor():
    pol = SLOAwareAdmissionPolicy(_NoopPolicy(), slo=2.0, floor_snap=0.2)
    act = pol.decide(_tel_with_p99(5.0), _view(0.25), now=0.0)
    assert act.admission_level == 0.0       # 0.125 < snap -> closed


def test_controller_records_admission_actions():
    ctrl = AutoscaleController(
        SLOAwareAdmissionPolicy(_NoopPolicy(), slo=2.0), mk("tmpl"),
        ControllerConfig(interval=5.0, cooldown=0.0))
    ctrl.telemetry = _tel_with_p99(5.0)
    events = ctrl.control_tick(_view(1.0), now=60.0, cluster_sids=["s0"])
    assert events == []                     # admission is not a membership event
    assert ctrl.admission_level == 0.5
    assert ctrl.records and ctrl.records[-1].action == "admission"


def test_closed_loop_admission_on_simulated_plane():
    """End to end on run_scenario: an interactive tenant burst triggers
    gate tightening (batch shed, no scale-out on a fixed budget) and the
    run loses nothing."""
    rng = random.Random(1234)
    spec = ServiceSpec(num_blocks=10, block_size_gb=1.32, cache_size_gb=2.5)
    servers = [Server(f"s{i}", rng.uniform(15, 40), rng.uniform(0.02, 0.2),
                      rng.uniform(0.02, 0.2)) for i in range(4)]
    template = Server("tmpl", 30.0, 0.05, 0.05)
    classes = [RequestClass("interactive", "chat", 0, slo_target=4.0),
               RequestClass("batch", "offline", 1, deadline=10.0)]
    sc = Scenario(horizon=300.0).tenant_burst(90.0, 120.0, 3.0, cls=0)
    pol = SLOAwareAdmissionPolicy(_NoopPolicy(), slo=4.0)
    ctrl = AutoscaleController(
        pol, template,
        ControllerConfig(interval=6.0, cooldown=12.0, warmup_lag=10.0,
                         max_servers=len(servers)))
    res = run_scenario(servers, spec, sc, policy="priority",
                       classes=classes, class_rates=[1.3, 0.7],
                       aging_rate=0.001, seed=0, controller=ctrl)
    assert res.completed_all
    assert res.n_rejected > 0
    assert set(res.result.rejected_class_ids.tolist()) == {1}
    admissions = [r for r in ctrl.records if r.action == "admission"]
    assert admissions, "the SLO breach must actuate the admission gate"
    assert any(e.kind == "auto-admission" for e in res.log)
    # fixed budget held: admission was the only actuation
    assert not [r for r in ctrl.records if r.action == "add"]


def test_telemetry_per_class_quantiles():
    tel = Telemetry(TelemetryConfig(window=100.0))
    for i in range(20):
        tel.record_completion(float(i), 1.0, cls=0)
        tel.record_completion(float(i), 10.0, cls=1)
    assert tel.response_quantile(50, cls=0) == pytest.approx(1.0)
    assert tel.response_quantile(50, cls=1) == pytest.approx(10.0)
    assert tel.response_quantile(50) == pytest.approx(5.5)
    assert tel.completions_in_window(cls=1) == 20
    assert math.isnan(tel.response_quantile(99, cls=7))
