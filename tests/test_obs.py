"""The flight recorder (repro.obs): metrics, span tracing, export.

Four pillars, matching the observability PR's acceptance gates:

* **metrics** — counters/gauges/log-scale histograms give streaming
  quantiles with bounded error, snapshots diff cleanly, and the autoscale
  telemetry window stays bounded past its completion cap;
* **zero interference** — a traced run is bit-identical to its untraced
  twin on every policy x engine x RNG-scheme combination, and the results
  store addresses traced and untraced runs by the same key;
* **span fidelity** — decoded timelines are self-consistent (queue end ==
  dispatch) and span sums reproduce the engines' reported response times
  bit for bit, on interpreter and compiled paths alike;
* **export** — Chrome-trace JSON round-trips with valid ph/ts/pid fields
  and one lane per serving chain.

Numpy-only except the explicitly jax-marked compiled-path test (the CI
``obs-smoke`` job runs this file in the minimal environment).
"""
import dataclasses
import json
import math
import random

import numpy as np
import pytest

from repro import api
from repro.autoscale.telemetry import Telemetry, TelemetryConfig
from repro.core import VECTORIZED_POLICIES, make_engine
from repro.core.engines import jax_available
from repro.core.simulator import poisson_arrivals
from repro.obs import (
    Counter,
    Gauge,
    LogHistogram,
    MetricsRegistry,
    RunTrace,
    Tracer,
    decode_sim_trace,
    export_chrome_trace,
    to_chrome_trace,
)
from repro.obs.trace import FIRST_CHAIN_LANE, QUEUE_LANE, RUN_LANE

RATES = [1.0, 0.8, 0.5]
CAPS = [2, 2, 4]

needs_jax = pytest.mark.skipif(not jax_available(),
                               reason="jax not installed")


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_counter_and_gauge():
    c = Counter()
    c.inc()
    c.inc(4)
    assert c.value == 5
    g = Gauge()
    assert math.isnan(g.value)
    g.set(2.5)
    assert g.value == 2.5


def test_log_histogram_streaming_quantiles():
    rng = np.random.default_rng(7)
    xs = rng.lognormal(mean=0.0, sigma=1.5, size=20_000)
    h = LogHistogram()
    h.record_many(xs)
    # geometric buckets at 40/decade: any quantile is within one bucket
    # ratio (10**(1/40) ~ 1.059) of the exact order statistic
    step = 10 ** (1 / 40)
    for q in (50.0, 90.0, 99.0):
        exact = float(np.percentile(xs, q))
        est = h.quantile(q)
        assert exact / step <= est <= exact * step, (q, exact, est)
    assert h.count == len(xs)
    assert h.min == xs.min() and h.max == xs.max()
    assert h.mean == pytest.approx(xs.mean())


def test_log_histogram_empty_and_extremes():
    h = LogHistogram()
    assert math.isnan(h.quantile(50))
    h.record(3.0)
    assert h.quantile(0) == 3.0 and h.quantile(100) == 3.0
    # out-of-range samples land in the clamp buckets but keep exact min/max
    h.record(1e-12)
    h.record(1e12)
    assert h.min == 1e-12 and h.max == 1e12
    assert h.quantile(100) == 1e12


def test_log_histogram_record_many_matches_scalar_path():
    xs = [0.01, 0.5, 2.0, 2.0, 77.0, 1e-9, 1e9]
    a, b = LogHistogram(), LogHistogram()
    for x in xs:
        a.record(x)
    b.record_many(xs)
    assert a.to_dict() == b.to_dict()


def test_log_histogram_merge():
    a, b = LogHistogram(), LogHistogram()
    a.record_many([1.0, 2.0])
    b.record_many([4.0, 8.0])
    a.merge(b)
    assert a.count == 4
    assert a.min == 1.0 and a.max == 8.0
    assert a.mean == pytest.approx(15.0 / 4)


def test_registry_get_or_create_and_type_guard():
    m = MetricsRegistry()
    assert m.counter("x") is m.counter("x")
    m.counter("x").inc(3)
    assert m.snapshot()["x"] == 3
    with pytest.raises(TypeError):
        m.gauge("x")


def test_snapshot_diff():
    m = MetricsRegistry()
    m.counter("jobs").inc(10)
    m.gauge("depth").set(float("nan"))
    m.histogram("resp").record(1.0)
    s0 = m.snapshot()
    assert s0.diff(s0) == {}                       # NaN == NaN
    m.counter("jobs").inc()
    m.counter("fresh").inc()
    d = m.snapshot().diff(s0)
    assert d["jobs"] == (11, 10)
    assert d["fresh"] == (1, None)
    assert "depth" not in d


def test_telemetry_buffer_bounded_with_histogram_fallback():
    """Past the completion cap the oldest records spill (never the
    newest) and quantiles fall back to the histogram sketch."""
    tel = Telemetry(TelemetryConfig(window=100.0, max_completions=64))
    n = 5_000
    for i in range(n):
        tel.record_completion(0.001 * i, 1.0 + (i % 100) / 100.0,
                              cls=i % 2)
    assert len(tel._completions) == 64
    assert tel.n_completions == n
    # newest records survive the spill
    assert tel._completions[-1][0] == pytest.approx(0.001 * (n - 1))
    p50, p99 = tel.response_quantile(50), tel.response_quantile(99)
    assert 1.3 < p50 < 1.7
    assert 1.8 < p99 <= 2.0 * (10 ** (1 / 40))
    assert not math.isnan(tel.response_quantile(99, cls=1))
    assert math.isnan(tel.response_quantile(99, cls=7))
    # the exact path is untouched below the cap
    tel2 = Telemetry(TelemetryConfig(window=100.0))
    for i in range(100):
        tel2.record_completion(1.0, float(i))
    assert tel2.response_quantile(50) == float(
        np.percentile(np.arange(100.0), 50))


# ---------------------------------------------------------------------------
# Span decode: self-consistency + bit-exact attribution
# ---------------------------------------------------------------------------

def _traced_run(policy="jffc", engine="vector", scheme="legacy", n=400,
                lam=4.8, seed=11, reconfigure_at=None, mode="restart"):
    arrivals = poisson_arrivals(lam, n, random.Random(seed))
    tr = Tracer()
    sim = make_engine(engine, RATES, CAPS, policy=policy, seed=seed,
                      rng_scheme=scheme, tracer=tr)
    sim.add_arrivals(arrivals)
    if reconfigure_at is not None:
        sim.run_until(reconfigure_at)
        sim.reconfigure([1.1, 0.6], [3, 3], at_time=reconfigure_at,
                        mode=mode)
    sim.run_to_completion()
    return sim, tr


def test_span_timeline_self_consistent():
    sim, tr = _traced_run(reconfigure_at=30.0)
    trace = decode_sim_trace(sim, tr)
    assert isinstance(trace, RunTrace)
    trace.self_check()
    assert trace.n_spans > 0
    assert trace.meta["n_epochs"] == 2
    assert trace.meta["unmatched_chain_jobs"] == 0
    assert any(m.name == "reconfigure" for m in trace.markers)
    # every request: queue span ends exactly where its service span starts
    for jid, spans in trace.spans_by_request().items():
        service = [s for s in spans if s.cat == "service"]
        queue = [s for s in spans if s.cat == "queue"]
        assert service, jid
        if queue:
            assert queue[-1].t1 == service[-1].t0


@pytest.mark.parametrize("mode", ["restart", "drain"])
def test_span_sums_reproduce_response_times_bitwise(mode):
    """service.t1 - queue.t0 equals the engine's reported response time
    bit for bit, for every completed job, through a recomposition."""
    sim, tr = _traced_run(reconfigure_at=25.0, mode=mode)
    res = sim.result()
    trace = decode_sim_trace(sim, tr)
    trace.self_check()
    by_req = trace.spans_by_request()
    assert len(by_req) == res.n_completed
    for jid, spans in by_req.items():
        t0 = min(s.t0 for s in spans)
        t1 = max(s.t1 for s in spans if s.cat == "service")
        assert t0 == sim.times[jid] and t1 == sim.fin[jid]
        assert t1 - t0 == sim.fin[jid] - sim.times[jid]


@pytest.mark.parametrize("scheme", ["legacy", "counter"])
@pytest.mark.parametrize("engine", ["vector", "batched"])
@pytest.mark.parametrize("policy", VECTORIZED_POLICIES)
def test_traced_bit_identical_to_untraced(policy, engine, scheme):
    """Tracing must never perturb the simulation: full SimResult parity
    on every policy x engine x RNG scheme."""
    arrivals = poisson_arrivals(4.8, 300, random.Random(13))
    plain = make_engine(engine, RATES, CAPS, policy=policy, seed=13,
                        rng_scheme=scheme)
    traced = make_engine(engine, RATES, CAPS, policy=policy, seed=13,
                         rng_scheme=scheme, tracer=Tracer())
    for sim in (plain, traced):
        sim.add_arrivals(arrivals)
        sim.run_to_completion()
    a, b = plain.result(), traced.result()
    assert np.array_equal(a.response_times, b.response_times)
    assert np.array_equal(a.waiting_times, b.waiting_times)
    assert a.n_completed == b.n_completed
    assert a.sim_time == b.sim_time
    trace = decode_sim_trace(traced, traced.tracer)
    trace.self_check()
    assert trace.meta["unmatched_chain_jobs"] == 0


@needs_jax
def test_compiled_path_chain_attribution_matches_interpreter():
    """The batched engine's native slot hints must agree with the
    interpreter decode's exact-replay attribution, job for job."""
    arrivals = poisson_arrivals(4.8, 3_000, random.Random(17))
    t = np.array([a[0] for a in arrivals])
    w = np.array([a[1] for a in arrivals])
    tv, tb = Tracer(), Tracer()
    v = make_engine("vector", RATES, CAPS, policy="jffc", seed=17,
                    tracer=tv)
    b = make_engine("batched", RATES, CAPS, policy="jffc", seed=17,
                    tracer=tb)
    b.scan_min_jobs = 1
    v.add_arrivals(arrivals)
    b.add_arrivals(t, w)
    v.run_to_completion()
    b.run_to_completion()
    assert b.trace_chain_of is not None          # compiled hints captured
    trv = decode_sim_trace(v, tv)
    trb = decode_sim_trace(b, tb)
    trv.self_check()
    trb.self_check()
    assert trb.meta["unmatched_chain_jobs"] == 0

    def chain_of(trace):
        return {jid: [s.args["chain"] for s in spans
                      if s.cat == "service"][-1]
                for jid, spans in trace.spans_by_request().items()}

    assert chain_of(trv) == chain_of(trb)


# ---------------------------------------------------------------------------
# Export
# ---------------------------------------------------------------------------

def test_chrome_trace_round_trips(tmp_path):
    sim, tr = _traced_run(reconfigure_at=30.0)
    trace = decode_sim_trace(sim, tr)
    path = tmp_path / "trace.json"
    doc = export_chrome_trace(trace, path)
    loaded = json.loads(path.read_text())
    assert loaded == json.loads(json.dumps(doc))
    events = loaded["traceEvents"]
    assert events
    phs = {e["ph"] for e in events}
    assert phs <= {"X", "i", "M"}
    names = {e["args"]["name"] for e in events
             if e["ph"] == "M" and e["name"] == "process_name"}
    # one metadata lane per serving chain, plus run + queue lanes
    assert sum(1 for n in names if n.startswith("chain[")) >= 2
    for e in events:
        assert isinstance(e["pid"], int) and e["pid"] >= RUN_LANE
        if e["ph"] == "X":
            assert e["ts"] >= 0 and e["dur"] >= 0
            assert e["pid"] >= QUEUE_LANE
        if e["ph"] == "i":
            assert e["s"] == "g"
    # service events carry their chain lane
    assert any(e["ph"] == "X" and e["pid"] >= FIRST_CHAIN_LANE
               for e in events)
    assert to_chrome_trace(trace)["otherData"]["n_epochs"] == 2


def test_tail_attribution_names_slowest_requests():
    sim, tr = _traced_run()
    trace = decode_sim_trace(sim, tr)
    top = trace.tail_attribution(k=3)
    assert len(top) == 3
    assert top[0]["response"] >= top[1]["response"] >= top[2]["response"]
    for row in top:
        assert row["response"] == pytest.approx(
            row["queue_s"] + row["service_s"])
        assert row["chain"] is not None


# ---------------------------------------------------------------------------
# API threading: planes, report, store keys
# ---------------------------------------------------------------------------

def _small_spec(**kw):
    return api.preset("failover_burst", n_target=250, base_rate=4.0, **kw)


def test_sim_plane_traced_run_is_identical_and_carries_trace():
    spec = _small_spec()
    r0 = api.run(spec)
    r1 = api.run(spec, trace=True)
    assert r0.diff(r1) == {}
    assert r0.trace is None
    r1.trace.self_check()
    assert any(m.cat == "scenario" for m in r1.trace.markers)
    assert "engine.completed" in r1.extras["metrics"]
    assert r1.extras["metrics"]["engine.completed"] == r1.n_completed


def test_live_plane_traced_smoke():
    spec = _small_spec()
    rep = api.run(spec, plane=api.LivePlane(engine="mock"), trace=True)
    rep.trace.self_check()
    assert rep.trace.meta["plane"] == "live"
    m = rep.extras["metrics"]
    assert m["orch.rounds"] > 0
    assert 0 < m["orch.completions"] <= rep.n_completed
    doc = to_chrome_trace(rep.trace)
    assert any(e["ph"] == "X" for e in doc["traceEvents"])


def test_store_key_unaffected_by_tracing(tmp_path):
    from repro.api.results import ResultsStore
    spec = _small_spec()
    store = ResultsStore(tmp_path)
    r1 = api.run(spec, store=store, trace=True)     # executes, saves
    r2 = api.run(spec, store=store)                 # cache hit, same key
    assert r2.trace is None
    assert r1.diff(r2) == {}
    # and a traced re-run bypasses the cache but hits the same key
    r3 = api.run(spec, store=store, trace=True)
    assert r3.trace is not None
    assert r1.diff(r3) == {}


def test_report_round_trip_strips_trace():
    rep = api.run(_small_spec(), trace=True)
    d = rep.to_dict()
    assert "trace" not in d and "raw" not in d
    json.dumps(d)                                   # JSON-safe
    back = api.RunReport.from_dict(d)
    assert back.trace is None
    assert back.diff(rep) == {}


def test_summary_line_per_class():
    rep = api.run(api.preset("overloaded_70_30"))
    line = rep.summary_line()
    assert "interactive p99" in line and "batch p99" in line
    assert "shed" in line
    # class-blind runs keep the single-line form
    line0 = api.run(_small_spec()).summary_line()
    assert "shed" not in line0
