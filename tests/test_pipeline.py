"""Pipeline-parallel chain execution: stage planning, s_c grant
conservation, bit-parity vs the monolithic engines, microbatch stream
invariance, LivePlane wiring, gauges/traces, and the shard_map grid path.

Multi-device cases skip cleanly on a single-device host; the CI jax matrix
runs this file under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
which makes them real."""
import json
import os
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import Server
from repro.core.chains import Chain
from repro.models import Model
from repro.serving import (
    ChainEngine,
    PagedChainEngine,
    PipelineChainEngine,
    Request,
    State,
    StageSpec,
    plan_stages,
    service_spec_for,
)
from repro.serving.kv_cache import PageAccounting

multi_device = pytest.mark.skipif(
    jax.local_device_count() < 2,
    reason="needs >= 2 local devices "
           "(set XLA_FLAGS=--xla_force_host_platform_device_count=8)")


@pytest.fixture(scope="module")
def tiny4():
    """4-layer reduced model + a 2-hop chain (2 blocks per hop)."""
    cfg = get("stablelm-1.6b").reduced(num_layers=4, vocab_size=128,
                                       attn_chunk_threshold=1 << 30)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    chain = Chain(("s0", "s1"), (2, 2), 1.0)
    return cfg, model, params, chain


def _mk_request(rid, prompt_len, n_new, seed=0):
    rng = np.random.default_rng(seed + rid)
    return Request(rid=rid,
                   prompt=rng.integers(1, 100, prompt_len).astype(np.int32),
                   max_new_tokens=n_new)


def _reqs(seed=0):
    # mixed non-pow2 prompts (boundary fixup) + enough decode to cross a
    # page boundary; request count > capacity to stagger admissions
    return [_mk_request(i, 5 + 7 * i, 12 + 4 * (i % 3), seed=seed)
            for i in range(5)]


def _drain(eng, reqs):
    pending = list(reqs)
    while pending or eng.requests:
        while pending and eng.has_free_slot and eng.admit(pending[0]):
            pending.pop(0)
        eng.step()
    return [list(r.output) for r in reqs]


# ---------------------------------------------------------------------------
# Stage planning
# ---------------------------------------------------------------------------

def test_plan_stages_one_stage_per_hop():
    plan = plan_stages([2, 2], 2)
    assert plan == [StageSpec(0, 0, 2, (0,)), StageSpec(1, 2, 4, (1,))]


def test_plan_stages_merges_toward_equal_layers():
    # [3, 1, 4] at S=2: merging hops 0+1 (4 layers) vs hop 2 (4 layers)
    # beats any other contiguous cut
    plan = plan_stages([3, 1, 4], 2)
    assert [(sp.lo, sp.hi, sp.hops) for sp in plan] \
        == [(0, 4, (0, 1)), (4, 8, (2,))]


def test_plan_stages_splits_inside_hops_when_oversubscribed():
    # more stages than hops: equal-layer cuts subdivide hops
    plan = plan_stages([2, 2], 4)
    assert [(sp.lo, sp.hi) for sp in plan] == [(0, 1), (1, 2), (2, 3), (3, 4)]
    assert [sp.hops for sp in plan] == [(0,), (0,), (1,), (1,)]


def test_plan_stages_covers_layers_contiguously():
    for blocks, S in [([5], 3), ([1, 1, 1], 8), ([4], 1), ([2, 3, 1, 6], 3)]:
        plan = plan_stages(blocks, S)
        L = sum(blocks)
        assert plan[0].lo == 0 and plan[-1].hi == L
        assert all(a.hi == b.lo for a, b in zip(plan, plan[1:]))
        assert all(sp.num_layers >= 1 for sp in plan)
        assert len(plan) == max(1, min(S, L))
    with pytest.raises(ValueError, match="positive"):
        plan_stages([2, 0], 2)


# ---------------------------------------------------------------------------
# s_c grant conservation
# ---------------------------------------------------------------------------

def test_stage_grants_conserve_s_c_exactly():
    """sum(per-stage grants) == the paper's s_c bit-for-bit, not approx."""
    spec = service_spec_for(get("qwen3-8b"), max_seq=4096)
    acct = PageAccounting.from_spec(spec, max_seq=4096)
    for counts in ([7], [3, 4], [2, 2, 3], [1] * 7, [6, 1], [5, 2, 9]):
        parts = acct.split(counts)
        assert len(parts) == len(counts)
        acc = 0.0
        for p in parts:
            acc += p.slot_gb
        assert acc == acct.slot_gb          # exact float equality
        # every stage keeps the slot's page geometry
        assert all(p.pages_per_slot == acct.pages_per_slot for p in parts)


def test_engine_plan_grants_conserve_s_c(tiny4):
    cfg, model, params, chain = tiny4
    spec = service_spec_for(cfg, max_seq=128)
    acct = PageAccounting.from_spec(spec, max_seq=128)
    for S in (1, 2, 3, 4):
        plan = plan_stages(chain.blocks, S)
        parts = acct.split([sp.num_layers for sp in plan])
        acc = 0.0
        for p in parts:
            acc += p.slot_gb
        assert acc == acct.slot_gb


# ---------------------------------------------------------------------------
# Bit-parity vs the monolithic engines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["slotted", "paged"])
def test_single_stage_matches_monolithic(tiny4, layout):
    """The CI parity anchor: num_stages=1 composes the monolithic graph."""
    cfg, model, params, chain = tiny4
    mono_cls = ChainEngine if layout == "slotted" else PagedChainEngine
    mono = mono_cls(model, params, chain, 4, 128)
    pipe = PipelineChainEngine(model, params, chain, 4, 128,
                               kv_layout=layout, num_stages=1)
    assert pipe.num_stages == 1
    out_mono = _drain(mono, _reqs())
    out_pipe = _drain(pipe, _reqs())
    assert out_mono == out_pipe


@pytest.mark.parametrize("layout,stages,micro", [
    ("paged", None, 1),      # one stage per hop
    ("paged", 2, 4),
    ("slotted", 4, 2),       # intra-hop splits
])
def test_multistage_matches_monolithic(tiny4, layout, stages, micro):
    """Splitting the block stack at hidden-state boundaries and regrouping
    rows into microbatches never changes the greedy streams."""
    cfg, model, params, chain = tiny4
    mono_cls = ChainEngine if layout == "slotted" else PagedChainEngine
    mono = mono_cls(model, params, chain, 4, 128)
    pipe = PipelineChainEngine(model, params, chain, 4, 128,
                               kv_layout=layout, num_stages=stages,
                               microbatches=micro)
    assert pipe.num_stages == (len(chain.blocks) if stages is None
                               else stages)
    assert _drain(mono, _reqs(seed=3)) == _drain(pipe, _reqs(seed=3))


def test_microbatch_count_is_stream_invariant(tiny4):
    """M=1 vs M=4: identical greedy token streams (rows are independent)."""
    cfg, model, params, chain = tiny4
    outs = []
    for micro in (1, 4):
        pipe = PipelineChainEngine(model, params, chain, 4, 128,
                                   kv_layout="paged", microbatches=micro)
        outs.append(_drain(pipe, _reqs(seed=7)))
    assert outs[0] == outs[1]


def test_pipeline_preemption_parity(tiny4):
    """Page exhaustion preempts the same victims in the same order as
    PagedChainEngine, and resubmission completes with identical streams."""
    cfg, model, params, chain = tiny4

    def run(factory):
        eng = factory()
        reqs = [_mk_request(i, 30, 40) for i in range(3)]
        for r in reqs:
            assert eng.admit(r)
        preempted = []
        while eng.requests:
            eng.step()
            preempted += eng.take_preempted()
        order = [r.rid for r in preempted]
        for r in preempted:
            assert r.state == State.QUEUED and r.retries == 1
            eng.admit(r)
            while eng.requests:
                eng.step()
        return order, [list(r.output) for r in reqs]

    mono = run(lambda: PagedChainEngine(model, params, chain, 1, 128,
                                        oversubscribe=3.0))
    pipe = run(lambda: PipelineChainEngine(model, params, chain, 1, 128,
                                           kv_layout="paged",
                                           oversubscribe=3.0,
                                           microbatches=2))
    assert mono == pipe
    assert mono[0], "pool pressure must preempt"


def test_pipeline_free_pages_surface(tiny4):
    """Paged pipelines report the shared pool; slotted ones raise
    AttributeError so the orchestrator's hasattr() gauge filter skips them.
    evict_all returns every page."""
    cfg, model, params, chain = tiny4
    paged = PipelineChainEngine(model, params, chain, 2, 64,
                                kv_layout="paged")
    total = paged.free_pages
    r = _mk_request(0, 20, 50)
    assert paged.admit(r)
    assert paged.free_pages < total
    evicted = paged.evict_all()
    assert [q.rid for q in evicted] == [0]
    assert paged.free_pages == total

    slotted = PipelineChainEngine(model, params, chain, 2, 64,
                                  kv_layout="slotted")
    assert not hasattr(slotted, "free_pages")


@multi_device
def test_pipeline_stages_on_distinct_devices(tiny4):
    """With >= 2 local devices the hop placement lands on distinct devices
    of the "stage" mesh, and cross-device handoff preserves parity."""
    cfg, model, params, chain = tiny4
    pipe = PipelineChainEngine(model, params, chain, 4, 128,
                               kv_layout="paged", microbatches=2)
    assert pipe.num_stages == 2
    assert pipe.devices[0] != pipe.devices[1]
    assert pipe.mesh.axis_names == ("stage",)
    mono = PagedChainEngine(model, params, chain, 4, 128)
    assert _drain(mono, _reqs(seed=11)) == _drain(pipe, _reqs(seed=11))


# ---------------------------------------------------------------------------
# distributed.mesh helpers
# ---------------------------------------------------------------------------

def test_ensure_host_device_flag(monkeypatch):
    from repro.distributed import ensure_host_device_flag
    from repro.distributed.mesh import HOST_DEVICE_FLAG

    monkeypatch.delenv("XLA_FLAGS", raising=False)
    ensure_host_device_flag(8)
    assert os.environ["XLA_FLAGS"] == f"{HOST_DEVICE_FLAG}=8"
    before = os.environ["XLA_FLAGS"]
    ensure_host_device_flag(4)              # already present: no-op
    assert os.environ["XLA_FLAGS"] == before
    monkeypatch.setenv("XLA_FLAGS", "--other_flag=1")
    ensure_host_device_flag(2)
    assert os.environ["XLA_FLAGS"] \
        == f"--other_flag=1 {HOST_DEVICE_FLAG}=2"


def test_stage_devices_round_robin():
    from repro.distributed import stage_devices, stage_mesh

    devs = list(jax.local_devices())
    got = stage_devices(len(devs) * 2 + 1)
    assert len(got) == len(devs) * 2 + 1
    assert all(g == devs[k % len(devs)] for k, g in enumerate(got))
    mesh = stage_mesh(len(devs) * 2 + 1)
    # meshes cannot repeat devices: the cycle appears exactly once
    assert mesh.devices.size == len(devs)
    with pytest.raises(ValueError, match="num_stages"):
        stage_devices(0)


# ---------------------------------------------------------------------------
# LivePlane wiring
# ---------------------------------------------------------------------------

def test_live_plane_pipeline_knobs_validate():
    from repro import api

    with pytest.raises(api.SpecError, match="parallelism"):
        api.LivePlane(parallelism="ring")
    with pytest.raises(api.SpecError, match="microbatches"):
        api.LivePlane(parallelism="pipeline", microbatches=0)
    with pytest.raises(api.SpecError, match="pipeline_stages"):
        api.LivePlane(parallelism="pipeline", pipeline_stages=0)
    # pipeline-only knobs are rejected in single mode (silent no-ops would
    # poison the results store)
    with pytest.raises(api.SpecError, match="parallelism"):
        api.LivePlane(microbatches=4)
    with pytest.raises(api.SpecError, match="parallelism"):
        api.LivePlane(pipeline_stages=2)


def test_live_plane_pipeline_store_key_and_round_trip():
    from repro import api

    single = api.LivePlane()
    pipe = api.LivePlane(parallelism="pipeline", pipeline_stages=2,
                         microbatches=4)
    assert single.store_key() != pipe.store_key()
    assert "parallelism=pipeline" in pipe.store_key()
    d = json.loads(json.dumps(pipe.to_dict()))
    back = api.LivePlane.from_dict(d)
    assert back.parallelism == "pipeline"
    assert back.pipeline_stages == 2 and back.microbatches == 4
    assert back.store_key() == pipe.store_key()


def test_live_plane_pipeline_rejects_mock_engine():
    from repro import api
    from repro.core import ServiceSpec

    spec = api.ExperimentSpec(
        cluster=api.ClusterSpec(
            servers=(Server("s0", 16.0, 0.05, 0.08),),
            service=ServiceSpec(num_blocks=4, block_size_gb=1.0,
                                cache_size_gb=0.1)),
        scenario=api.ScenarioSpec(horizon=5.0),
        workload=api.WorkloadSpec(base_rate=1.0),
        seed=0)
    with pytest.raises(api.SpecError, match="engine='jax'"):
        api.run(spec, plane=api.LivePlane(parallelism="pipeline"))


# ---------------------------------------------------------------------------
# Gauges + flight-recorder stage lanes
# ---------------------------------------------------------------------------

def test_eviction_publishes_gauges_immediately(tiny4):
    """A page freed by failover shows in orch.free_pages without waiting
    for the next decode round (no phantom page leaks in traces)."""
    from functools import partial

    from repro.obs import MetricsRegistry
    from repro.serving import Orchestrator, OrchestratorConfig

    cfg, model, params, chain = tiny4
    spec = service_spec_for(cfg, max_seq=128)
    mem = (spec.block_size_gb * cfg.num_layers
           + spec.cache_size_gb * cfg.num_layers * 6)
    servers = [Server(f"s{i}", mem, 0.05, 0.02 * (1 + i % 2))
               for i in range(4)]
    orch = Orchestrator(
        servers, spec, model, params, 0.5,
        OrchestratorConfig(max_seq=128,
                           engine_factory=partial(PagedChainEngine,
                                                  page_size=16)))
    orch.metrics = MetricsRegistry()
    for i in range(6):
        orch.submit(_mk_request(i, 8, 30))
    orch.step()
    victim = orch.engines[0].chain.servers[0]
    orch.fail_server(victim)
    snap = orch.metrics.snapshot().as_dict()
    live_pages = sum(e.free_pages for e in orch.engines
                     if hasattr(e, "free_pages"))
    assert snap["orch.free_pages"] == live_pages
    assert snap["orch.batch_occupancy"]["count"] > 0
    orch.drain()


def test_trace_records_stage_lanes(tiny4):
    """trace_schedule=True records the 1F wavefront; decode_orchestrator_
    trace turns it into one lane per (chain, stage) with tick spans."""
    from repro.obs.decode import decode_orchestrator_trace

    cfg, model, params, chain = tiny4
    pipe = PipelineChainEngine(model, params, chain, 4, 128,
                               kv_layout="paged", microbatches=2,
                               trace_schedule=True)
    reqs = [_mk_request(i, 8, 6) for i in range(4)]
    now = 0.0
    pending = list(reqs)
    while pending or pipe.requests:
        while pending and pipe.has_free_slot and pipe.admit(pending[0], now):
            pending.pop(0)
        pipe.step(now)
        now += 0.5
    assert pipe.stage_schedule
    # every round's ticks obey the wavefront: stage k runs ubatch t - k
    for e in pipe.stage_schedule:
        assert e["ubatch"] == e["tick"] - e["stage"]
    orch = types.SimpleNamespace(engines=[pipe], finished=list(reqs),
                                 failed=[], deferred=[])
    tr = decode_orchestrator_trace(orch)
    assert tr.meta["n_stage_spans"] == len(pipe.stage_schedule)
    stage_lanes = [v for v in tr.lanes.values() if "/stage[" in v]
    assert len(stage_lanes) == pipe.num_stages
    spans = [s for s in tr.spans if s.cat == "pipeline"]
    assert len(spans) == len(pipe.stage_schedule)
    assert all(s.t1 > s.t0 for s in spans)


# ---------------------------------------------------------------------------
# shard_map grid dispatch (PR 6 sweep path on real shards)
# ---------------------------------------------------------------------------

def _grid_inputs(S=13, n=60):
    from repro.core.engines import jax_scan as js

    rng = np.random.default_rng(0)
    times = np.sort(rng.exponential(1.0, (S, n)), axis=1)
    works = rng.exponential(2.0, (S, n))
    us = rng.random((S, n))
    slot_rate, slot_prio, slot_chain = js.slot_layout(
        [2.0, 1.0], [3, 3], [0, 1])
    return js, times, works, us, slot_rate, slot_prio, slot_chain


def test_grid_impl_rejects_unknown():
    js, times, works, *_rest = _grid_inputs(2, 8)
    slot_rate, slot_prio = _rest[1], _rest[2]
    with pytest.raises(ValueError, match="grid impl"):
        js.run_jffc_scan_grid(times, works, slot_rate, slot_prio,
                              impl="spmd")


@multi_device
def test_shard_map_matches_pmap_bitwise():
    """The migration gate: shard_map (default) == legacy pmap == vmap,
    exact equality, including non-divisible row counts (padding)."""
    js, times, works, us, slot_rate, slot_prio, slot_chain = _grid_inputs()
    ref = js.run_jffc_scan_grid(times, works, slot_rate, slot_prio,
                                devices=1)
    for impl in ("shard_map", "pmap"):
        got = js.run_jffc_scan_grid(times, works, slot_rate, slot_prio,
                                    impl=impl)
        assert all(np.array_equal(a, b) for a, b in zip(ref, got)), impl
    for pol in ("jffs", "jsq"):
        ref = js.run_event_scan_grid(pol, times, works, us, slot_rate,
                                     slot_chain, [2.0, 1.0], [3, 3], [0, 1],
                                     devices=1)
        sm = js.run_event_scan_grid(pol, times, works, us, slot_rate,
                                    slot_chain, [2.0, 1.0], [3, 3], [0, 1],
                                    impl="shard_map")
        pm = js.run_event_scan_grid(pol, times, works, us, slot_rate,
                                    slot_chain, [2.0, 1.0], [3, 3], [0, 1],
                                    impl="pmap")
        assert all(np.array_equal(a, b) for a, b in zip(ref, sm)), pol
        assert all(np.array_equal(a, b) for a, b in zip(ref, pm)), pol


@multi_device
def test_sharded_sweep_parity_through_run_grid():
    """ROADMAP gate: the sweep's run_grid one-pass path is bit-stable on a
    real multi-shard host (devices=1 vs all visible devices)."""
    from repro.core.engines.batched import run_grid
    from repro.core.workload import poisson_exponential_np

    traces = [poisson_exponential_np(4.8, 400, seed=s) for s in range(5)]
    times = np.stack([t for t, _ in traces])
    works = np.stack([w for _, w in traces])
    for policy in ("jffc", "sed"):
        a = run_grid(policy, [2.0, 1.0], [2, 4], times, works, devices=1)
        b = run_grid(policy, [2.0, 1.0], [2, 4], times, works)
        for x, y in zip(a, b):
            assert np.array_equal(x.response_times, y.response_times)
            assert np.array_equal(x.waiting_times, y.waiting_times)
            assert x.sim_time == y.sim_time
