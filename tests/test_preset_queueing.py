"""The queueing-theory preset gate: ``mmc_queue`` vs closed forms.

A single pre-composed chain of ``c`` slots at rate ``mu`` is a textbook
M/M/c queue, where the paper's occupancy bounds
(:func:`repro.core.queueing.occupancy_lower_bound` /
``occupancy_upper_bound``) coincide with the exact birth-death closed
form.  Little's law converts the simulated mean response time into a
mean occupancy directly comparable against that closed form — the
ROADMAP's "assert the queueing presets against theory" leftover.
"""
import math

import pytest

import repro.api as api
from repro.api import preset
from repro.core.queueing import (
    occupancy_lower_bound,
    occupancy_upper_bound,
    response_time_bounds,
)


def test_mmc_preset_spec_shape():
    spec = preset("mmc_queue", mu=2.0, c=4, rho=0.5, n_jobs=1000)
    assert spec.cluster.job_servers == ((2.0, 4),)
    assert spec.workload.base_rate == pytest.approx(0.5 * 2.0 * 4)
    assert spec.workload.generator == "poisson"
    assert spec.warmup_fraction == 0.1
    # lossless round trip like every preset
    assert api.ExperimentSpec.from_dict(spec.to_dict()) == spec


def test_mmc_preset_rejects_unstable_rho():
    from repro.api import SpecError

    with pytest.raises(SpecError, match="rho"):
        preset("mmc_queue", rho=1.0)
    with pytest.raises(SpecError, match="rho"):
        preset("mmc_queue", rho=-0.1)


@pytest.mark.parametrize("mu,c,rho,n_jobs", [
    (1.0, 8, 0.7, 30_000),
    (2.0, 4, 0.5, 30_000),
    (1.0, 4, 0.8, 60_000),       # heavier traffic mixes slower
    (1.5, 6, 0.85, 60_000),
])
def test_mmc_preset_matches_closed_form(mu, c, rho, n_jobs):
    """Simulated mean occupancy (Little's law) within 10% of the exact
    M/M/c birth-death value; the one-chain bounds must coincide."""
    js = ((mu, c),)
    lam = rho * mu * c
    lower = occupancy_lower_bound(js, lam)
    upper = occupancy_upper_bound(js, lam)
    assert lower == pytest.approx(upper, rel=1e-12)   # single chain: exact

    spec = preset("mmc_queue", mu=mu, c=c, rho=rho, n_jobs=n_jobs)
    rep = api.run(spec)
    assert rep.completed_all
    occ_sim = lam * rep.mean_response()               # Little's law
    assert occ_sim == pytest.approx(lower, rel=0.10), \
        f"M/M/{c} rho={rho}: simulated occupancy {occ_sim:.3f} vs " \
        f"closed form {lower:.3f}"
    # mean response inside the (coinciding) theoretical response bounds
    t_lo, t_hi = response_time_bounds(js, lam)
    assert t_lo == pytest.approx(t_hi, rel=1e-12)
    assert rep.mean_response() == pytest.approx(t_lo, rel=0.10)


def test_mmc_preset_engines_agree():
    v = api.run(preset("mmc_queue", n_jobs=5000))
    b = api.run(preset("mmc_queue", n_jobs=5000, engine="batched"))
    assert v.mean_response() == b.mean_response()
    assert v.p99() == b.p99()
