"""Scenario engine regressions: scripted failures lose no requests, bursts
degrade JFFC's p99 far less than random dispatch, and the orchestrator
replays the same timelines on a live system."""
import random

import numpy as np
import pytest

from conftest import run_scenario_spec as run_scenario
from repro.core import (
    Scenario,
    ScenarioEvent,
    Server,
    ServiceSpec,
    compose_or_degrade,
)

SPEC = ServiceSpec(num_blocks=10, block_size_gb=1.32, cache_size_gb=0.11)


def cluster(n=8, seed=1234):
    """Same construction as the shared ``small_cluster`` fixture, with the
    size adjustable for the degraded/blackout cases."""
    rng = random.Random(seed)
    return [
        Server(f"s{i}", rng.uniform(15, 40), rng.uniform(0.02, 0.2),
               rng.uniform(0.02, 0.2))
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# Scenario description mechanics
# ---------------------------------------------------------------------------

def test_event_validation():
    with pytest.raises(ValueError):
        ScenarioEvent(1.0, "explode")
    with pytest.raises(ValueError):
        ScenarioEvent(1.0, "fail")            # needs sid
    with pytest.raises(ValueError):
        ScenarioEvent(1.0, "add")             # needs server


def test_arrival_phases_overlay():
    sc = Scenario(horizon=100.0).burst(20.0, 10.0, 4.0).burst(60.0, 20.0, 2.0)
    phases = sc.arrival_phases(1.0)
    assert phases == [(0.0, 20.0, 1.0), (20.0, 30.0, 4.0), (30.0, 60.0, 1.0),
                      (60.0, 80.0, 2.0), (80.0, 100.0, 1.0)]


def test_burst_raises_local_arrival_rate():
    sc = Scenario(horizon=300.0).burst(100.0, 50.0, 8.0)
    times, works = sc.generate_arrivals(2.0, seed=3)
    assert len(times) == len(works)
    in_burst = np.sum((times >= 100.0) & (times < 150.0))
    # expected 8*2*50 = 800 burst arrivals vs 2*250 = 500 elsewhere
    assert in_burst > 600
    base = np.sum(times < 100.0)
    assert 120 < base < 300                   # ~200 expected


# ---------------------------------------------------------------------------
# Failure / recovery regressions (the FailSafe regime)
# ---------------------------------------------------------------------------

def test_fixtures_match_module_constants(small_cluster, small_spec):
    """The shared conftest fixtures and this module's helpers describe the
    same canonical cluster, so results are comparable across test modules."""
    assert small_spec == SPEC
    local = cluster()
    assert len(small_cluster) == len(local)
    assert [s.sid for s in small_cluster] == [s.sid for s in local]
    assert all(a == b for a, b in zip(small_cluster, local))


def test_failure_mid_run_loses_no_requests(small_cluster, small_spec):
    servers = small_cluster
    sc = Scenario(horizon=200.0).fail(60.0, "s3").fail(90.0, "s1")
    res = run_scenario(servers, small_spec, sc, base_rate=3.0, seed=0)
    assert res.completed_all
    assert res.result.n_completed == res.n_jobs
    assert res.reconfigurations == 2
    assert np.all(res.result.waiting_times >= 0)
    # response times of restarted jobs include the failure penalty but stay
    # finite
    assert np.isfinite(res.result.response_times).all()


def test_failure_under_load_restarts_in_flight_jobs(small_cluster, small_spec):
    servers = small_cluster
    sc = Scenario(horizon=10.0).fail(5.0, "s0")
    res = run_scenario(servers, small_spec, sc, base_rate=60.0, seed=0)
    assert res.completed_all
    assert res.restarts > 0                   # slots were busy at the failure
    assert res.log[0].requeued == res.restarts


def test_recovery_restores_service_rate(small_cluster, small_spec):
    servers = small_cluster
    sc = (Scenario(horizon=100.0)
          .fail(30.0, "s2")
          .recover(60.0, servers[2]))
    res = run_scenario(servers, small_spec, sc, base_rate=3.0, seed=1)
    assert res.completed_all
    fail_entry, add_entry = res.log
    assert fail_entry.kind == "fail" and add_entry.kind == "add"
    assert add_entry.total_rate > fail_entry.total_rate


def test_infeasible_demand_degrades_but_serves():
    # two small servers cannot meet rho_bar-scaled demand -> degraded c=1
    servers = cluster(n=4)
    sc = Scenario(horizon=6.0).fail(3.0, "s0").fail(3.0, "s1")
    res = run_scenario(servers, SPEC, sc, base_rate=40.0, seed=2)
    assert res.completed_all                  # arrivals stop; backlog drains
    assert any(e.degraded for e in res.log)


def test_slowdown_triggers_recomposition(small_cluster, small_spec):
    servers = small_cluster
    sc = Scenario(horizon=50.0).slowdown(25.0, "s5", 3.0)
    res = run_scenario(servers, small_spec, sc, base_rate=3.0, seed=3)
    assert res.completed_all
    assert res.log[0].kind == "slowdown"
    assert res.reconfigurations == 1


# ---------------------------------------------------------------------------
# Burst regression (the DeepServe regime): JFFC beats random dispatch on p99
# ---------------------------------------------------------------------------

def test_burst_p99_jffc_beats_random_dispatch(small_cluster, small_spec):
    servers = small_cluster
    sc = Scenario(horizon=400.0).burst(200.0, 40.0, 6.0)
    arr = sc.generate_arrivals(2.0, seed=7)   # identical trace for both
    p99 = {}
    for policy in ("jffc", "random"):
        res = run_scenario(servers, small_spec, sc, base_rate=2.0,
                           policy=policy, seed=0, arrivals=arr)
        assert res.completed_all
        p99[policy] = res.p99()
    assert p99["jffc"] < p99["random"], p99


def test_compose_or_degrade_empty_cluster():
    rates, caps, keys, degraded = compose_or_degrade([], SPEC, 1.0, 0.7)
    assert rates == [] and caps == [] and keys == []
    assert degraded


@pytest.mark.parametrize("policy", ("jffc", "jffs", "random"))
def test_total_blackout_and_recovery(policy):
    """Every server dies mid-run, then the whole cluster returns: arrivals
    park during the outage and every job still completes — for every
    vectorized policy, not just the central-queue one."""
    servers = cluster(n=4)
    sc = Scenario(horizon=40.0)
    for s in servers:
        sc.fail(10.0, s.sid)
    for s in servers:
        sc.recover(20.0, s)
    res = run_scenario(servers, SPEC, sc, base_rate=5.0, policy=policy, seed=0)
    assert res.completed_all
    assert res.result.n_completed == res.n_jobs
    assert res.log[len(servers) - 1].n_chains == 0      # true blackout
    # jobs that arrived during the outage waited for the recovery
    assert float(np.max(res.result.waiting_times)) > 5.0
