"""Serving engine + orchestrator: generation correctness, JFFC dispatch,
failover, elasticity, straggler feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import Server
from repro.models import Model
from repro.serving import (
    ChainEngine,
    Orchestrator,
    OrchestratorConfig,
    Request,
    State,
    service_spec_for,
    tau_estimates,
)


@pytest.fixture(scope="module")
def tiny():
    cfg = get("stablelm-1.6b").reduced(num_layers=2, vocab_size=128,
                                       attn_chunk_threshold=1 << 30)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def greedy_rollout(model, params, prompt, n_new):
    """Oracle: re-run the full forward for every generated token."""
    toks = list(prompt)
    for _ in range(n_new):
        logits = model.forward_train(params, {"tokens": jnp.asarray([toks])})
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def _mk_request(rid, prompt_len, n_new, seed=0):
    rng = np.random.default_rng(seed + rid)
    return Request(rid=rid, prompt=rng.integers(1, 100, prompt_len).astype(np.int32),
                   max_new_tokens=n_new)


def test_engine_generates_same_as_oracle(tiny):
    cfg, model, params = tiny
    from repro.core.chains import Chain

    eng = ChainEngine(model, params, Chain(("s0",), (cfg.num_layers,), 1.0),
                      capacity=3, max_seq=128)
    reqs = [_mk_request(i, 8 + 3 * i, 6) for i in range(3)]
    for r in reqs:
        assert eng.admit(r)
    while eng.requests:
        eng.step()
    for r in reqs:
        oracle = greedy_rollout(model, params, r.prompt, 6)
        assert r.output == oracle, f"req {r.rid}: {r.output} vs {oracle}"


def test_engine_bucketed_prefill_matches_exact(tiny):
    """Prompt length that is NOT a power of two must still match the oracle
    (exercises the boundary re-decode path)."""
    cfg, model, params = tiny
    from repro.core.chains import Chain

    eng = ChainEngine(model, params, Chain(("s0",), (cfg.num_layers,), 1.0),
                      capacity=1, max_seq=128)
    r = _mk_request(0, 13, 5)     # 13 -> bucket 16
    assert eng.admit(r)
    while eng.requests:
        eng.step()
    assert r.output == greedy_rollout(model, params, r.prompt, 5)


def _orchestrator(tiny, n_servers=4, lam=0.5, mem=None, max_seq=128):
    cfg, model, params = tiny
    spec = service_spec_for(cfg, max_seq=max_seq)
    # memory sized so each server holds the whole reduced model + some slots
    mem = mem if mem is not None else (spec.block_size_gb * cfg.num_layers
                                       + spec.cache_size_gb * cfg.num_layers * 6)
    servers = [Server(f"s{i}", mem, 0.05, 0.02 * (1 + i % 2)) for i in range(n_servers)]
    orch = Orchestrator(servers, spec, model, params, lam,
                        OrchestratorConfig(max_seq=max_seq))
    return orch


def test_orchestrator_serves_batch(tiny):
    orch = _orchestrator(tiny)
    reqs = [_mk_request(i, 8, 4) for i in range(8)]
    for r in reqs:
        orch.submit(r)
    orch.drain()
    assert all(r.state == State.DONE for r in reqs)
    stats = orch.stats()
    assert stats["finished"] == 8 and stats["queued"] == 0
    # outputs must match the oracle regardless of which chain served them
    cfg, model, params = tiny
    for r in reqs[:3]:
        assert r.output == greedy_rollout(model, params, r.prompt, 4)


def test_jffc_prefers_fastest_engine(tiny):
    orch = _orchestrator(tiny)
    rates = [e.chain.rate for e in orch.engines]
    assert rates == sorted(rates, reverse=True)
    r = _mk_request(0, 8, 64)
    orch.submit(r)
    assert r.chain_idx == 0, "first request must land on the fastest chain"


def test_queue_when_capacity_exhausted(tiny):
    orch = _orchestrator(tiny, n_servers=2)
    total_cap = sum(e.capacity for e in orch.engines)
    reqs = [_mk_request(i, 8, 8) for i in range(total_cap + 3)]
    for r in reqs:
        orch.submit(r)
    assert len(orch.queue) == 3
    orch.drain()
    assert all(r.state == State.DONE for r in reqs)


def test_failover_requeues_and_completes(tiny):
    orch = _orchestrator(tiny, n_servers=4)
    reqs = [_mk_request(i, 8, 6) for i in range(6)]
    for r in reqs:
        orch.submit(r)
    # advance a couple of rounds, then kill the server carrying chain 0
    orch.step(); orch.step()
    victim = orch.engines[0].chain.servers[0]
    requeued = orch.fail_server(victim)
    assert victim not in {s for e in orch.engines for s in e.chain.servers}
    orch.drain()
    assert all(r.state == State.DONE for r in reqs)
    # outputs still correct (context preserved across failover)
    cfg, model, params = tiny
    for r in reqs:
        assert r.output == greedy_rollout(model, params, r.prompt, 6), (
            f"req {r.rid} diverged after failover (requeued={requeued})")


def test_elastic_add_server_increases_rate(tiny):
    orch = _orchestrator(tiny, n_servers=2)
    before = orch.allocation.total_rate
    cfg, model, params = tiny
    spec = orch.spec
    mem = spec.block_size_gb * cfg.num_layers + spec.cache_size_gb * cfg.num_layers * 6
    orch.add_server(Server("new", mem, 0.01, 0.005))
    assert orch.allocation.total_rate > before


def test_straggler_feedback_triggers_recompose(tiny):
    orch = _orchestrator(tiny, n_servers=4)
    n0 = orch.recompositions
    sid = orch.engines[0].chain.servers[0]
    for _ in range(12):
        orch.report_tau(sid, 3.0)
    assert orch.tau_scale[sid] > 1.5
    assert orch.recompositions > n0


def test_orchestrator_runs_scripted_scenario(tiny):
    """A core.scenarios timeline (failure -> straggler -> recovery) driven
    through the live orchestrator completes every request."""
    from repro.core import Scenario

    orch = _orchestrator(tiny, n_servers=4)
    victim = orch.engines[0].chain.servers[0]
    victim_server = orch.servers[victim]
    straggler = orch.engines[-1].chain.servers[0]
    scenario = (Scenario(horizon=10.0)
                .fail(2.0, victim)
                .slowdown(4.0, straggler, 1.6)
                .recover(6.0, victim_server))
    reqs = [_mk_request(i, 8, 4) for i in range(6)]
    from repro.api import drive_orchestrator

    summary = drive_orchestrator(orch, scenario, reqs, dt=1.0)
    assert all(r.state == State.DONE for r in reqs)
    assert summary["finished"] == 6 and summary["failed"] == 0
    kinds = [e["kind"] for e in summary["events"]]
    assert kinds == ["fail", "slowdown", "add"]
    assert summary["recompositions"] >= 2     # fail + add at minimum
    # the failed server really left and came back
    assert victim in orch.servers


# ---------------------------------------------------------------------------
# Paged KV cache + continuous batching
# ---------------------------------------------------------------------------

def _paged_engine(tiny, capacity=3, max_seq=128, **kw):
    from repro.core.chains import Chain
    from repro.serving import PagedChainEngine

    cfg, model, params = tiny
    return PagedChainEngine(model, params,
                            Chain(("s0",), (cfg.num_layers,), 1.0),
                            capacity, max_seq, **kw)


def test_paged_engine_generates_same_as_oracle(tiny):
    cfg, model, params = tiny
    eng = _paged_engine(tiny)
    # non-pow2 prompts exercise the boundary fixup; 40 new tokens cross
    # page boundaries (page_size 16) during decode
    reqs = [_mk_request(i, 8 + 3 * i, 40) for i in range(3)]
    for r in reqs:
        assert eng.admit(r)
    while eng.requests:
        eng.step()
    for r in reqs:
        oracle = greedy_rollout(model, params, r.prompt, 40)
        assert r.output == oracle, f"req {r.rid}: {r.output} vs {oracle}"


def test_slotted_paged_greedy_parity(tiny):
    """The layout contract: greedy token streams are bit-identical between
    SlotCache and PagedCache engines, with staggered admissions (continuous
    batching gathers different batch shapes round to round)."""
    from repro.core.chains import Chain
    from repro.serving import ChainEngine, PagedChainEngine

    cfg, model, params = tiny
    chain = Chain(("s0",), (cfg.num_layers,), 1.0)
    outs = {}
    for name, factory in [("slotted", ChainEngine), ("paged", PagedChainEngine)]:
        eng = factory(model, params, chain, 4, 128)
        reqs = [_mk_request(i, 5 + 7 * i, 25, seed=3) for i in range(7)]
        pending = list(reqs)
        while pending or eng.requests:
            while pending and eng.has_free_slot and eng.admit(pending[0]):
                pending.pop(0)
            eng.step()
        outs[name] = [r.output for r in reqs]
    assert outs["slotted"] == outs["paged"]


def test_paged_pool_exhaustion_defers_admission(tiny):
    """Oversubscribed slots + a drained page pool: admit refuses (returns
    False) instead of corrupting; freed pages make the request admissible."""
    eng = _paged_engine(tiny, capacity=2, max_seq=128, oversubscribe=3.0)
    # budget: 2 slots * 8 pages = 16 pages over 6 slots; each 50-token
    # prompt takes 4 pages, so the 5th admission finds slots but no pages
    reqs = [_mk_request(i, 50, 2) for i in range(5)]
    admitted = [eng.admit(r) for r in reqs]
    assert admitted == [True, True, True, True, False]
    assert eng.has_free_slot            # a slot is free; pages are not
    assert reqs[4].state == State.QUEUED
    while eng.requests:
        eng.step()
    assert eng.admit(reqs[4])           # pages released -> admissible now
    while eng.requests:
        eng.step()
    cfg, model, params = tiny
    for r in reqs:
        assert r.output == greedy_rollout(model, params, r.prompt, 2)


def test_paged_released_pages_are_reusable(tiny):
    """Admit/complete cycles return every page; the free stack refills and
    reused (dirty) pages decode correctly."""
    eng = _paged_engine(tiny, capacity=2, max_seq=64)
    total = eng.cache.free_pages
    cfg, model, params = tiny
    for round_ in range(3):
        reqs = [_mk_request(10 * round_ + i, 20, 4, seed=round_) for i in range(2)]
        for r in reqs:
            assert eng.admit(r)
        while eng.requests:
            eng.step()
        assert eng.cache.free_pages == total
        for r in reqs:
            assert r.output == greedy_rollout(model, params, r.prompt, 4)


def test_paged_preemption_requeues_youngest(tiny):
    """Page exhaustion during decode preempts the youngest request with its
    generated tokens preserved; the orchestrator-level resubmit completes it
    with oracle-correct output (context re-prefilled)."""
    eng = _paged_engine(tiny, capacity=1, max_seq=128, oversubscribe=3.0)
    # 1 slot of budget = 8 pages; three 30-token prompts (2 pages each) fit,
    # but decoding 40 tokens each needs more pages than the pool holds
    reqs = [_mk_request(i, 30, 40) for i in range(3)]
    for r in reqs:
        assert eng.admit(r)
    preempted = []
    while eng.requests:
        eng.step()
        preempted += eng.take_preempted()
    assert preempted, "pool pressure must preempt"
    assert all(r.state == State.QUEUED and r.retries == 1 for r in preempted)
    # youngest-first victim order: request 0 (oldest) is never preempted
    assert all(r.rid != 0 for r in preempted)
    cfg, model, params = tiny
    for r in preempted:                  # progress preserved in context
        assert list(r.context_tokens[:30]) == list(r.prompt)
        assert len(r.output) > 0
        eng.admit(r)
        while eng.requests:
            eng.step()
    for r in reqs:
        assert r.output == greedy_rollout(model, params, r.prompt, 40)


def test_paged_orchestrator_end_to_end(tiny):
    """Full orchestrator over paged engines: dispatch, preemption drain,
    recompose survival, and the new data-plane metrics."""
    from functools import partial

    from repro.obs import MetricsRegistry
    from repro.serving import PagedChainEngine

    cfg, model, params = tiny
    spec = service_spec_for(cfg, max_seq=128)
    mem = (spec.block_size_gb * cfg.num_layers
           + spec.cache_size_gb * cfg.num_layers * 6)
    servers = [Server(f"s{i}", mem, 0.05, 0.02 * (1 + i % 2)) for i in range(4)]
    orch = Orchestrator(
        servers, spec, model, params, 0.5,
        OrchestratorConfig(max_seq=128,
                           engine_factory=partial(PagedChainEngine,
                                                  page_size=16)))
    orch.metrics = MetricsRegistry()
    reqs = [_mk_request(i, 8, 6) for i in range(8)]
    for r in reqs:
        orch.submit(r)
    orch.step(); orch.step()
    # engines whose (chain, capacity) survive a recompose keep their block
    # tables (same servers -> same composition -> every engine survives)
    before = {tuple(e.chain.servers): (id(e), id(e.cache.block_table))
              for e in orch.engines}
    in_flight = sum(e.num_active for e in orch.engines)
    orch._recompose_preserving(2.0, drain=True)
    for e in orch.engines:
        prev_id, prev_bt = before[tuple(e.chain.servers)]
        assert id(e) == prev_id and id(e.cache.block_table) == prev_bt
    assert sum(e.num_active for e in orch.engines) == in_flight
    orch.drain()
    assert all(r.state == State.DONE for r in reqs)
    for r in reqs[:3]:
        assert r.output == greedy_rollout(model, params, r.prompt, 6)
    snap = orch.metrics.snapshot().as_dict()
    assert "orch.free_pages" in snap
    assert "orch.prefill_buckets" in snap
    assert snap["orch.batch_occupancy"]["count"] > 0


def test_page_accounting_round_trips_s_c():
    """pages <-> s_c is exact: a full slot's pages occupy exactly the s_c
    gigabytes GCA granted for that slot."""
    from repro.serving import PAGE_SIZE, PageAccounting

    cfg = get("qwen3-8b")
    spec = service_spec_for(cfg, max_seq=4096)
    acct = PageAccounting.from_spec(spec, max_seq=4096)
    assert acct.page_size == PAGE_SIZE
    assert acct.pages_per_slot == 4096 // PAGE_SIZE
    assert acct.gb_for_pages(acct.pages_per_slot) == spec.cache_size_gb
    assert acct.gb_for_pages(acct.pages_for_slots(3)) \
        == pytest.approx(3 * spec.cache_size_gb)
    assert acct.pages_for_tokens(1) == 1
    assert acct.pages_for_tokens(PAGE_SIZE) == 1
    assert acct.pages_for_tokens(PAGE_SIZE + 1) == 2


def test_slot_cache_active_slots_tracks_set(tiny):
    from repro.serving import SlotCache

    cfg, model, params = tiny
    sc = SlotCache(model, capacity=4, max_seq=32)
    a, b = sc.acquire(), sc.acquire()
    assert sorted([a, b]) == sc.active_slots
    sc.release(a)
    assert sc.active_slots == [b]
    sc.release(b)
    assert sc.active_slots == []


def test_prefill_jit_cache_is_bounded(tiny):
    """Admitting many distinct prompt-length buckets never holds more than
    PREFILL_BUCKET_LIMIT live prefill specializations."""
    from repro.serving.engine import PREFILL_BUCKET_LIMIT

    eng = _paged_engine(tiny, capacity=1, max_seq=2048)
    for plen in (3, 17, 33, 65, 129, 257, 513, 1025, 1500, 2000):
        r = _mk_request(plen, plen, 1)
        assert eng.admit(r)
        assert eng.prefill_bucket_count <= PREFILL_BUCKET_LIMIT
        while eng.requests:
            eng.step()
    assert eng.prefill_bucket_count <= PREFILL_BUCKET_LIMIT


def test_service_spec_and_tau_estimates():
    cfg = get("qwen3-8b")
    spec = service_spec_for(cfg, max_seq=32768, tp_degree=16)
    # qwen3-8b layer ~ 193M params -> ~0.386 GB bf16 /16 ~ 0.024 GB
    assert 0.01 < spec.block_size_gb < 0.05
    # KV 2*8*128*2B * 32768 / 16 ~ 0.0168 GB
    assert 0.005 < spec.cache_size_gb < 0.05
    tau = tau_estimates(cfg, mean_in_tokens=2000, mean_out_tokens=20)
    assert 0.0 < tau < 1.0
    # hybrid: windowed layers shrink s_c; ssm: state-only
    hy = service_spec_for(get("hymba-1.5b"), max_seq=32768)
    full_kv = get("hymba-1.5b").kv_bytes_per_token_per_layer() * 32768 / (1024.0 ** 3)
    assert hy.cache_size_gb < 0.35 * full_kv
    xl = service_spec_for(get("xlstm-350m"), max_seq=524288)
    assert xl.cache_size_gb < 0.01  # state, not KV: tiny and S-independent
