"""Serving engine + orchestrator: generation correctness, JFFC dispatch,
failover, elasticity, straggler feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import Server
from repro.models import Model
from repro.serving import (
    ChainEngine,
    Orchestrator,
    OrchestratorConfig,
    Request,
    State,
    service_spec_for,
    tau_estimates,
)


@pytest.fixture(scope="module")
def tiny():
    cfg = get("stablelm-1.6b").reduced(num_layers=2, vocab_size=128,
                                       attn_chunk_threshold=1 << 30)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def greedy_rollout(model, params, prompt, n_new):
    """Oracle: re-run the full forward for every generated token."""
    toks = list(prompt)
    for _ in range(n_new):
        logits = model.forward_train(params, {"tokens": jnp.asarray([toks])})
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def _mk_request(rid, prompt_len, n_new, seed=0):
    rng = np.random.default_rng(seed + rid)
    return Request(rid=rid, prompt=rng.integers(1, 100, prompt_len).astype(np.int32),
                   max_new_tokens=n_new)


def test_engine_generates_same_as_oracle(tiny):
    cfg, model, params = tiny
    from repro.core.chains import Chain

    eng = ChainEngine(model, params, Chain(("s0",), (cfg.num_layers,), 1.0),
                      capacity=3, max_seq=128)
    reqs = [_mk_request(i, 8 + 3 * i, 6) for i in range(3)]
    for r in reqs:
        assert eng.admit(r)
    while eng.requests:
        eng.step()
    for r in reqs:
        oracle = greedy_rollout(model, params, r.prompt, 6)
        assert r.output == oracle, f"req {r.rid}: {r.output} vs {oracle}"


def test_engine_bucketed_prefill_matches_exact(tiny):
    """Prompt length that is NOT a power of two must still match the oracle
    (exercises the boundary re-decode path)."""
    cfg, model, params = tiny
    from repro.core.chains import Chain

    eng = ChainEngine(model, params, Chain(("s0",), (cfg.num_layers,), 1.0),
                      capacity=1, max_seq=128)
    r = _mk_request(0, 13, 5)     # 13 -> bucket 16
    assert eng.admit(r)
    while eng.requests:
        eng.step()
    assert r.output == greedy_rollout(model, params, r.prompt, 5)


def _orchestrator(tiny, n_servers=4, lam=0.5, mem=None, max_seq=128):
    cfg, model, params = tiny
    spec = service_spec_for(cfg, max_seq=max_seq)
    # memory sized so each server holds the whole reduced model + some slots
    mem = mem if mem is not None else (spec.block_size_gb * cfg.num_layers
                                       + spec.cache_size_gb * cfg.num_layers * 6)
    servers = [Server(f"s{i}", mem, 0.05, 0.02 * (1 + i % 2)) for i in range(n_servers)]
    orch = Orchestrator(servers, spec, model, params, lam,
                        OrchestratorConfig(max_seq=max_seq))
    return orch


def test_orchestrator_serves_batch(tiny):
    orch = _orchestrator(tiny)
    reqs = [_mk_request(i, 8, 4) for i in range(8)]
    for r in reqs:
        orch.submit(r)
    orch.drain()
    assert all(r.state == State.DONE for r in reqs)
    stats = orch.stats()
    assert stats["finished"] == 8 and stats["queued"] == 0
    # outputs must match the oracle regardless of which chain served them
    cfg, model, params = tiny
    for r in reqs[:3]:
        assert r.output == greedy_rollout(model, params, r.prompt, 4)


def test_jffc_prefers_fastest_engine(tiny):
    orch = _orchestrator(tiny)
    rates = [e.chain.rate for e in orch.engines]
    assert rates == sorted(rates, reverse=True)
    r = _mk_request(0, 8, 64)
    orch.submit(r)
    assert r.chain_idx == 0, "first request must land on the fastest chain"


def test_queue_when_capacity_exhausted(tiny):
    orch = _orchestrator(tiny, n_servers=2)
    total_cap = sum(e.capacity for e in orch.engines)
    reqs = [_mk_request(i, 8, 8) for i in range(total_cap + 3)]
    for r in reqs:
        orch.submit(r)
    assert len(orch.queue) == 3
    orch.drain()
    assert all(r.state == State.DONE for r in reqs)


def test_failover_requeues_and_completes(tiny):
    orch = _orchestrator(tiny, n_servers=4)
    reqs = [_mk_request(i, 8, 6) for i in range(6)]
    for r in reqs:
        orch.submit(r)
    # advance a couple of rounds, then kill the server carrying chain 0
    orch.step(); orch.step()
    victim = orch.engines[0].chain.servers[0]
    requeued = orch.fail_server(victim)
    assert victim not in {s for e in orch.engines for s in e.chain.servers}
    orch.drain()
    assert all(r.state == State.DONE for r in reqs)
    # outputs still correct (context preserved across failover)
    cfg, model, params = tiny
    for r in reqs:
        assert r.output == greedy_rollout(model, params, r.prompt, 6), (
            f"req {r.rid} diverged after failover (requeued={requeued})")


def test_elastic_add_server_increases_rate(tiny):
    orch = _orchestrator(tiny, n_servers=2)
    before = orch.allocation.total_rate
    cfg, model, params = tiny
    spec = orch.spec
    mem = spec.block_size_gb * cfg.num_layers + spec.cache_size_gb * cfg.num_layers * 6
    orch.add_server(Server("new", mem, 0.01, 0.005))
    assert orch.allocation.total_rate > before


def test_straggler_feedback_triggers_recompose(tiny):
    orch = _orchestrator(tiny, n_servers=4)
    n0 = orch.recompositions
    sid = orch.engines[0].chain.servers[0]
    for _ in range(12):
        orch.report_tau(sid, 3.0)
    assert orch.tau_scale[sid] > 1.5
    assert orch.recompositions > n0


def test_orchestrator_runs_scripted_scenario(tiny):
    """A core.scenarios timeline (failure -> straggler -> recovery) driven
    through the live orchestrator completes every request."""
    from repro.core import Scenario

    orch = _orchestrator(tiny, n_servers=4)
    victim = orch.engines[0].chain.servers[0]
    victim_server = orch.servers[victim]
    straggler = orch.engines[-1].chain.servers[0]
    scenario = (Scenario(horizon=10.0)
                .fail(2.0, victim)
                .slowdown(4.0, straggler, 1.6)
                .recover(6.0, victim_server))
    reqs = [_mk_request(i, 8, 4) for i in range(6)]
    from repro.api import drive_orchestrator

    summary = drive_orchestrator(orch, scenario, reqs, dt=1.0)
    assert all(r.state == State.DONE for r in reqs)
    assert summary["finished"] == 6 and summary["failed"] == 0
    kinds = [e["kind"] for e in summary["events"]]
    assert kinds == ["fail", "slowdown", "add"]
    assert summary["recompositions"] >= 2     # fail + add at minimum
    # the failed server really left and came back
    assert victim in orch.servers


def test_service_spec_and_tau_estimates():
    cfg = get("qwen3-8b")
    spec = service_spec_for(cfg, max_seq=32768, tp_degree=16)
    # qwen3-8b layer ~ 193M params -> ~0.386 GB bf16 /16 ~ 0.024 GB
    assert 0.01 < spec.block_size_gb < 0.05
    # KV 2*8*128*2B * 32768 / 16 ~ 0.0168 GB
    assert 0.005 < spec.cache_size_gb < 0.05
    tau = tau_estimates(cfg, mean_in_tokens=2000, mean_out_tokens=20)
    assert 0.0 < tau < 1.0
    # hybrid: windowed layers shrink s_c; ssm: state-only
    hy = service_spec_for(get("hymba-1.5b"), max_seq=32768)
    full_kv = get("hymba-1.5b").kv_bytes_per_token_per_layer() * 32768 / (1024.0 ** 3)
    assert hy.cache_size_gb < 0.35 * full_kv
    xl = service_spec_for(get("xlstm-350m"), max_seq=524288)
    assert xl.cache_size_gb < 0.01  # state, not KV: tiny and S-independent
