"""Vectorized engine vs. the scalar oracle: bit-identical traces, segmented
runs, no-op reconfigurations, and agreement with the Section 3.2 queueing
predictions (Thm 3.7 bounds, exact CTMC)."""
import random

import numpy as np
import pytest

from repro.core import (
    POLICIES,
    VectorSimulator,
    VECTORIZED_POLICIES,
    exact_occupancy_ctmc,
    occupancy_lower_bound,
    occupancy_upper_bound,
    simulate,
    simulate_vectorized,
)
from repro.core.simulator import poisson_arrivals

SERVERS = [(1.0, 2), (0.8, 2), (0.5, 4)]   # nu = 5.6
RATES = [m for m, _ in SERVERS]
CAPS = [c for _, c in SERVERS]


def _scalar(policy, arrivals, seed):
    pol = POLICIES[policy](RATES, CAPS, random.Random(seed + 1))
    return simulate(pol, arrivals)


def _identical(a, b):
    assert a.n_completed == b.n_completed
    assert np.array_equal(a.response_times, b.response_times)
    assert np.array_equal(a.waiting_times, b.waiting_times)
    assert np.array_equal(a.service_times, b.service_times)
    assert a.sim_time == b.sim_time


@pytest.mark.parametrize("policy", VECTORIZED_POLICIES)
@pytest.mark.parametrize("lam", [2.0, 4.5, 5.4])      # light / heavy / near-sat
@pytest.mark.parametrize("seed", [0, 3])
def test_bit_identical_response_times(policy, lam, seed):
    arrivals = poisson_arrivals(lam, 8_000, random.Random(seed))
    _identical(_scalar(policy, arrivals, seed),
               simulate_vectorized(policy, SERVERS, arrivals, seed=seed))


def test_bit_identical_zero_warmup_and_full_trace():
    arrivals = poisson_arrivals(4.5, 5_000, random.Random(11))
    sc = simulate(POLICIES["jffc"](RATES, CAPS, random.Random(12)), arrivals,
                  warmup_fraction=0.0)
    vec = simulate_vectorized("jffc", SERVERS, arrivals, seed=11,
                              warmup_fraction=0.0)
    _identical(sc, vec)
    assert vec.n_completed == len(arrivals)
    # every job obeys arrival <= start <= finish
    assert np.all(vec.waiting_times >= 0)
    assert np.all(vec.service_times > 0)


def test_segmented_run_equals_one_shot():
    """run_until pauses must not perturb the trajectory."""
    arrivals = poisson_arrivals(4.5, 6_000, random.Random(5))
    one = simulate_vectorized("jffc", SERVERS, arrivals, seed=5)
    sim = VectorSimulator(RATES, CAPS, policy="jffc", seed=6)
    sim.add_arrivals(arrivals)
    horizon = arrivals[-1][0]
    for frac in (0.1, 0.25, 0.5, 0.9):
        sim.run_until(frac * horizon)
    sim.run_to_completion()
    _identical(one, sim.result(warmup_fraction=0.1))


def test_noop_reconfigure_preserves_trajectory():
    """Reconfiguring to the identical chain set (same identities) must keep
    every in-flight job and not change a single response time."""
    arrivals = poisson_arrivals(4.5, 6_000, random.Random(9))
    one = simulate_vectorized("jffc", SERVERS, arrivals, seed=9)
    keys = [f"chain{k}" for k in range(len(RATES))]
    sim = VectorSimulator(RATES, CAPS, policy="jffc", seed=10, keys=keys)
    sim.add_arrivals(arrivals)
    horizon = arrivals[-1][0]
    for frac in (0.3, 0.6):
        sim.run_until(frac * horizon)
        requeued = sim.reconfigure(RATES, CAPS, at_time=frac * horizon,
                                   keys=keys)
        assert requeued == 0
    sim.run_to_completion()
    _identical(one, sim.result(warmup_fraction=0.1))


def test_reconfigure_restarts_lose_no_jobs():
    """Dropping to a smaller chain set mid-run re-dispatches in-flight work;
    everything still completes exactly once."""
    arrivals = poisson_arrivals(4.5, 4_000, random.Random(21))
    sim = VectorSimulator(RATES, CAPS, policy="jffc", seed=22,
                          keys=["a", "b", "c"])
    sim.add_arrivals(arrivals)
    t_half = arrivals[2000][0]
    sim.run_until(t_half)
    requeued = sim.reconfigure([1.0, 0.5], [2, 4], at_time=t_half,
                               keys=["a", "c"])   # chain "b" retired
    assert requeued >= 0
    sim.run_to_completion()
    res = sim.result(warmup_fraction=0.0)
    assert res.n_completed == len(arrivals)
    assert sim.queue_len() == 0 and sim.in_flight == 0
    assert np.all(res.waiting_times >= 0)
    # completions are unique (exactly-once)
    assert len(set(sim.comp)) == len(sim.comp) == len(arrivals)


def test_mean_occupancy_within_thm37_bounds():
    """Little's-law occupancy of a long JFFC run sits inside the Theorem 3.7
    birth-death bounds (5% slack for finite-run noise)."""
    lam = 4.5
    res = simulate_vectorized(
        "jffc", SERVERS, poisson_arrivals(lam, 60_000, random.Random(1)),
        seed=1, warmup_fraction=0.2)
    occ = lam * res.mean_response       # PASTA + Little
    lo = occupancy_lower_bound(SERVERS, lam)
    hi = occupancy_upper_bound(SERVERS, lam)
    assert lo * 0.95 <= occ <= hi * 1.05, (lo, occ, hi)


def test_mean_occupancy_matches_exact_ctmc():
    """Small system: simulated occupancy matches the truncated-CTMC ground
    truth within 8%."""
    servers = [(1.0, 2), (0.6, 1)]
    lam = 2.0
    exact = exact_occupancy_ctmc(servers, lam, queue_cap=400)
    res = simulate_vectorized(
        "jffc", servers, poisson_arrivals(lam, 80_000, random.Random(2)),
        seed=2, warmup_fraction=0.2)
    occ = lam * res.mean_response
    assert occ == pytest.approx(exact, rel=0.08)


def test_dedicated_policy_conservation():
    """jffs / random: all jobs complete, waits non-negative, service times
    consistent with some chain's rate."""
    arrivals = poisson_arrivals(4.0, 5_000, random.Random(3))
    for policy in ("jffs", "random"):
        res = simulate_vectorized(policy, SERVERS, arrivals, seed=3,
                                  warmup_fraction=0.0)
        assert res.n_completed == len(arrivals)
        assert np.all(res.waiting_times >= -1e-12)


def test_vectorized_rejects_unsupported_policy():
    with pytest.raises(ValueError):
        VectorSimulator(RATES, CAPS, policy="round-robin")


# ---------------------------------------------------------------------------
# Multi-tenant refactor: single-default-class parity guard
# ---------------------------------------------------------------------------

def test_single_class_parity_guard():
    """The multi-tenant refactor must be invisible to class-blind runs:
    with one default class, (a) attaching class labels to a jffc run
    changes nothing, and (b) the priority engine reproduces jffc bit for
    bit (tier 0 + no aging = FIFO pulls, no shedding)."""
    arrivals = poisson_arrivals(4.8, 8_000, random.Random(17))
    base = simulate_vectorized("jffc", SERVERS, arrivals, seed=17)
    tt = np.array([a[0] for a in arrivals])
    ww = np.array([a[1] for a in arrivals])
    labeled = simulate_vectorized(
        "jffc", SERVERS, (tt, ww, np.zeros(len(tt), dtype=np.int64)), seed=17)
    _identical(base, labeled)
    pri = simulate_vectorized("priority", SERVERS, arrivals, seed=17)
    _identical(base, pri)
    assert pri.n_rejected == 0
    assert np.all(pri.class_ids == 0)


def test_priority_multiclass_matches_scalar_oracle():
    """Vector priority engine vs. the scalar PriorityJFFC oracle on a
    two-class mix, with and without aging."""
    from repro.core import PriorityJFFC, RequestClass, classed_poisson_mix

    classes = [RequestClass("interactive", "chat", 0, slo_target=2.0),
               RequestClass("batch", "offline", 1)]
    t, w, c = classed_poisson_mix([3.6, 1.6], 1_200.0, seed=5)
    tuples = [(float(ti), float(wi), 0, 0, int(ci))
              for ti, wi, ci in zip(t, w, c)]
    for aging in (0.0, 0.02):
        pol = PriorityJFFC(RATES, CAPS, random.Random(6), classes=classes,
                           aging_rate=aging)
        sc = simulate(pol, tuples)
        vec = simulate_vectorized("priority", SERVERS, (t, w, c), seed=5,
                                  classes=classes, aging_rate=aging)
        _identical(sc, vec)
        assert np.array_equal(sc.class_ids, vec.class_ids)
