"""Training substrate: optimizer semantics, grad accumulation equivalence,
checkpoint round-trip + crash-safe restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.models import Model
from repro.training import (
    AdamWConfig,
    TrainConfig,
    checkpoint,
    data,
    init_train_state,
    make_train_step,
)


def tiny_model():
    return Model(get("stablelm-1.6b").reduced(num_layers=2, vocab_size=256))


def tiny_batch(cfg, key, B=4, S=32):
    kt, kl = jax.random.split(key)
    return {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size),
    }


def test_train_loss_decreases_over_steps():
    model = tiny_model()
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=50,
                                             state_dtype="float32"))
    params, opt = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, tcfg))
    it = data.batches(model.cfg, 4, 33, seed=0)
    losses = []
    for i in range(12):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses


def test_grad_accum_equivalent_to_full_batch():
    model = tiny_model()
    batch = tiny_batch(model.cfg, jax.random.PRNGKey(1), B=8)
    base = TrainConfig(optimizer=AdamWConfig(lr=1e-3, state_dtype="float32",
                                             warmup_steps=1, total_steps=10))
    accum = TrainConfig(optimizer=base.optimizer, grad_accum=4)
    params, opt = init_train_state(model, base, jax.random.PRNGKey(0))
    p1, _, m1 = jax.jit(make_train_step(model, base))(params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(model, accum))(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        p1, p2)
    assert max(jax.tree.leaves(diffs)) < 5e-2   # bf16 params: one-ulp-scale drift


def test_clip_norm_engages():
    from repro.training.optimizer import adamw_init, adamw_update, global_norm

    cfg = AdamWConfig(clip_norm=0.5, state_dtype="float32")
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": 100.0 * jnp.ones((4, 4))}
    state = adamw_init(cfg, params)
    _, _, metrics = adamw_update(cfg, params, grads, state)
    assert float(metrics["grad_norm"]) == pytest.approx(400.0, rel=1e-5)


def test_checkpoint_roundtrip(tmp_path):
    model = tiny_model()
    tcfg = TrainConfig()
    params, opt = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 7, {"params": params, "opt": opt}, metadata={"note": "t"})
    restored, manifest = checkpoint.restore_latest(d, {"params": params, "opt": opt})
    assert manifest["step"] == 7
    same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)), restored["params"], params)
    assert all(jax.tree.leaves(same))


def test_checkpoint_latest_pointer_and_multiple_steps(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"w": jnp.arange(8.0)}
    checkpoint.save(d, 1, tree)
    checkpoint.save(d, 5, {"w": jnp.arange(8.0) * 2})
    assert checkpoint.latest_step(d) == 5
    restored, _ = checkpoint.restore_latest(d, tree)
    np.testing.assert_allclose(np.asarray(restored["w"]), np.arange(8.0) * 2)


def test_checkpoint_crash_leaves_no_partial_state(tmp_path):
    """A temp dir from an interrupted save must not be visible via LATEST."""
    d = str(tmp_path / "ckpt")
    tree = {"w": jnp.arange(4.0)}
    checkpoint.save(d, 3, tree)
    os.makedirs(os.path.join(d, ".tmp_interrupted"), exist_ok=True)  # simulated crash
    assert checkpoint.latest_step(d) == 3
    restored, _ = checkpoint.restore_latest(d, tree)
    np.testing.assert_allclose(np.asarray(restored["w"]), np.arange(4.0))


def test_checkpoint_structure_mismatch_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        checkpoint.restore(os.path.join(d, "step_00000001"), {"b": jnp.zeros(3)})


def test_save_async_completes(tmp_path):
    d = str(tmp_path / "ckpt")
    t = checkpoint.save_async(d, 2, {"w": jnp.ones(16)})
    t.join(timeout=30)
    assert checkpoint.latest_step(d) == 2


def test_data_pipeline_determinism_and_sharding():
    cfg = get("qwen3-8b").reduced()
    a = next(data.batches(cfg, 2, 16, seed=3, shard=0, num_shards=2))
    b = next(data.batches(cfg, 2, 16, seed=3, shard=0, num_shards=2))
    c = next(data.batches(cfg, 2, 16, seed=3, shard=1, num_shards=2))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])       # deterministic
    assert not np.array_equal(a["tokens"], c["tokens"])           # shard-disjoint
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
